//! POET inside the discrete-event cluster — the Fig. 7 / Tab. 3–4 engine.
//!
//! This runs the *same coupled simulation* as [`super::driver`] (real grid,
//! real native chemistry, real rounding/keys, real DHT protocol over real
//! window memory), but each rank's time is simulated: chemistry charges
//! the calibrated [`ChemCost`] (PHREEQC time), DHT operations run through
//! the calibrated network model, and every step ends in a barrier — so
//! load imbalance from the moving reaction front emerges naturally, which
//! is exactly what limits the reference run's scaling in the paper
//! ("the simulation has already reached the maximum degree of
//! parallelization when using only one node").
//!
//! With `pipeline > 1` the per-step surrogate lookups are *pipelined*:
//! every rank keeps up to `pipeline` DHT reads/writes in flight on the
//! engine's lanes (the batched access pattern of the threaded driver),
//! while chemistry remains serialized per rank — a rank has one CPU, but
//! its NIC can overlap many one-sided ops (DESIGN.md §3).
//!
//! Grid scaling: the paper's 500x1500 grid is scaled down (default 60x180)
//! with per-cell chemistry cost kept at the paper's magnitude; simulated
//! runtimes therefore scale with the cell ratio, and the *relative* gains
//! (Tab. 3) are the reproduction target.

use std::collections::VecDeque;

use crate::dht::replica::{ReplOut, ReplReadSm, ReplSm};
use crate::dht::{DhtConfig, DhtOutcome, DhtSm, DhtStats, Variant};
use crate::net::{NetConfig, Network};
use crate::rma::fault::FaultPlan;
use crate::rma::sim::{SimCluster, SimReport};
use crate::rma::{WorkItem, Workload};
use crate::sim::Time;

use super::chemistry::{integrate_cell, ChemCost, N_OUT};
use super::grid::GridState;
use super::key::{cell_key, pack_row, unpack_value};
use super::transport;

/// Initial poll interval for a lane waiting on rank-level work (ns).
/// Never hit at `pipeline == 1` (a single lane always has work or is at
/// the barrier).  Idle lanes back off exponentially up to
/// [`LANE_POLL_MAX_NS`] so a long serial-chemistry drain does not flood
/// the event queue with polls; the cap bounds how late a lane can notice
/// the end of the step (small vs the >= 1 ms step times).
const LANE_POLL_NS: u64 = 2_000;
const LANE_POLL_MAX_NS: u64 = 16_000;

/// Configuration of a DES POET run.
#[derive(Clone, Debug)]
pub struct PoetDesCfg {
    pub nranks: u32,
    pub ny: usize,
    pub nx: usize,
    pub steps: usize,
    pub dt: f64,
    pub cf: [f64; 2],
    pub inj_rows: usize,
    pub digits: u32,
    /// None = reference run (no DHT).
    pub variant: Option<Variant>,
    pub win_bytes: usize,
    pub cost: ChemCost,
    /// Per-rank, per-step fixed overhead (transport + halo exchange),
    /// ns.
    pub step_overhead_ns: u64,
    /// Per-step collective-synchronization cost factor: charged as
    /// `step_sync_ns * log2(nranks)` — the serial component that caps the
    /// reference run's scaling in Fig. 7.
    pub step_sync_ns: u64,
    /// Per-owned-cell transport compute, ns.
    pub transport_ns_per_cell: u64,
    /// In-flight DHT ops per rank (pipeline depth; 1 = the classic
    /// blocking per-cell loop).
    pub pipeline: u32,
    /// k-way replication factor for the surrogate DHT (DESIGN.md §9;
    /// 1 = the paper's single-owner placement, clamped to `nranks`).
    pub replicas: u32,
    /// Deterministic chaos injection: kill `(rank, at_ns)`'s DHT storage
    /// at the given simulated instant — the shard is lost, reads fail
    /// over to replicas, the compute plane keeps running.
    pub kill_rank_at: Option<(u32, u64)>,
}

impl PoetDesCfg {
    pub fn scaled(nranks: u32, variant: Option<Variant>) -> Self {
        Self {
            nranks,
            ny: 60,
            nx: 180,
            steps: 500,
            dt: 2000.0,
            cf: [0.5, 0.0],
            inj_rows: 12,
            digits: 4,
            variant,
            win_bytes: 2 << 20,
            cost: ChemCost::default(),
            step_overhead_ns: 250_000,
            step_sync_ns: 300_000,
            transport_ns_per_cell: 500,
            pipeline: 1,
            replicas: 1,
            kill_rank_at: None,
        }
    }
}

/// Results of a DES POET run.
#[derive(Clone, Debug)]
pub struct PoetDesResult {
    /// Simulated runtime of the chemistry+transport loop [s].
    pub runtime_s: f64,
    pub chem_cells: u64,
    pub hits: u64,
    pub misses: u64,
    pub dht: DhtStats,
    pub sim: SimReport,
    pub max_dolomite: f64,
    /// Per-step (hits, misses) — the hit-rate trajectory a mid-run rank
    /// kill is judged by (all zeros for reference runs).
    pub step_hits: Vec<(u64, u64)>,
}

impl PoetDesResult {
    pub fn hit_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.hits as f64 / t as f64
        }
    }

    /// Mean hit rate over the step range `[lo, hi)` (clamped).
    pub fn hit_rate_over(&self, lo: usize, hi: usize) -> f64 {
        let hi = hi.min(self.step_hits.len());
        let lo = lo.min(hi);
        let (h, m) = self.step_hits[lo..hi]
            .iter()
            .fold((0u64, 0u64), |(h, m), (sh, sm)| (h + sh, m + sm));
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }
}

/// What a (rank, lane) currently has in flight.
enum LaneJob {
    Idle,
    /// Step-start overhead Think (transport + sync) in flight.
    Overhead,
    /// DHT read of `cell` outstanding; key kept for the miss path.
    Read { cell: usize, key: Vec<u8> },
    /// Chemistry Think in flight; on completion the result is written to
    /// the DHT (`write` = Some) or just applied (reference run).
    Compute { write: Option<(Vec<u8>, [f64; N_OUT])> },
    /// DHT write outstanding (`replica`: a non-primary fan-out copy —
    /// kept out of the application write metrics, DESIGN.md §9).
    Write { replica: bool },
}

struct RankCur {
    step: usize,
    /// Next unread cell index within this rank's owned range.
    next_cell: usize,
    reads_inflight: u32,
    writes_inflight: u32,
    /// Cells whose read missed, awaiting (serialized) chemistry.
    compute_q: VecDeque<(usize, Vec<u8>)>,
    /// Replica fan-out writes awaiting a free lane (the primary write
    /// leaves on the computing lane; the k-1 copies queue here so the
    /// fan-out pipelines over sibling lanes instead of serializing).
    write_q: VecDeque<DhtSm>,
    /// A chemistry Think is in flight (one CPU per rank).
    computing: bool,
    /// Step overhead charged / in flight.
    overhead_done: bool,
    overhead_inflight: bool,
    /// All of this step's work drained; lanes park at the barrier.
    step_complete: bool,
}

impl RankCur {
    fn new() -> Self {
        Self {
            step: 0,
            next_cell: 0,
            reads_inflight: 0,
            writes_inflight: 0,
            compute_q: VecDeque::new(),
            write_q: VecDeque::new(),
            computing: false,
            overhead_done: false,
            overhead_inflight: false,
            step_complete: false,
        }
    }

    fn drained(&self) -> bool {
        self.reads_inflight == 0
            && self.writes_inflight == 0
            && !self.computing
            && self.compute_q.is_empty()
            && self.write_q.is_empty()
    }
}

struct PoetWorkload {
    cfg: PoetDesCfg,
    lanes: u32,
    dht: Option<DhtConfig>,
    grid: GridState,
    scratch: Vec<f64>,
    inflow: Vec<f64>,
    ranges: Vec<(usize, usize)>,
    cur: Vec<RankCur>,
    lane_job: Vec<LaneJob>,
    /// Per-lane idle-poll backoff (reset whenever the lane gets work).
    poll_ns: Vec<u64>,
    /// Last step whose transport has been applied to the grid.
    transport_applied: i64,
    stats: DhtStats,
    hits: u64,
    misses: u64,
    /// Per-step (hits, misses) trajectory.
    step_hits: Vec<(u64, u64)>,
    chem_cells: u64,
}

impl PoetWorkload {
    fn new(cfg: PoetDesCfg) -> Self {
        let (bg, inj, min0) = super::chemistry::default_waters();
        let grid = GridState::new(cfg.ny, cfg.nx, &bg, &min0);
        let mut inflow = Vec::with_capacity(bg.len() * 2);
        for s in 0..bg.len() {
            inflow.push(inj[s]);
            inflow.push(bg[s]);
        }
        let cells = grid.cells();
        let n = cfg.nranks as usize;
        let lanes = cfg.pipeline.max(1);
        let ranges = (0..n)
            .map(|r| (r * cells / n, (r + 1) * cells / n))
            .collect();
        let dht = cfg
            .variant
            .map(|v| {
                DhtConfig::poet(v, cfg.nranks, cfg.win_bytes)
                    .with_replicas(cfg.replicas)
            });
        Self {
            lanes,
            dht,
            grid,
            scratch: Vec::new(),
            inflow,
            ranges,
            cur: (0..n).map(|_| RankCur::new()).collect(),
            lane_job: (0..n * lanes as usize).map(|_| LaneJob::Idle).collect(),
            poll_ns: vec![LANE_POLL_NS; n * lanes as usize],
            transport_applied: -1,
            stats: DhtStats::default(),
            hits: 0,
            misses: 0,
            step_hits: vec![(0, 0); cfg.steps],
            chem_cells: 0,
            cfg,
        }
    }

    /// The deterministic failure detector: the workload knows the fault
    /// plan, so a rank is "detected" failed from its kill instant on —
    /// an oracle detector, which is exactly what a reproducible chaos
    /// run wants (ops already in flight still execute in degraded mode).
    fn rank_dead(&self, target: u32, now: Time) -> bool {
        matches!(self.cfg.kill_rank_at, Some((r, at)) if r == target && now >= at)
    }

    #[inline]
    fn ctx(&self, rank: u32, lane: u32) -> usize {
        (rank * self.lanes + lane) as usize
    }

    fn apply_transport(&mut self, step: usize) {
        if self.transport_applied >= step as i64 {
            return;
        }
        transport::advect_step(
            &mut self.grid.solutes,
            &mut self.scratch,
            self.cfg.ny,
            self.cfg.nx,
            &self.inflow,
            self.cfg.cf,
            self.cfg.inj_rows,
        );
        self.transport_applied = step as i64;
    }

    /// Idle poll with per-lane exponential backoff.
    fn poll(&mut self, ctx: usize) -> WorkItem<ReplSm> {
        let ns = self.poll_ns[ctx];
        self.poll_ns[ctx] = (ns * 2).min(LANE_POLL_MAX_NS);
        WorkItem::Think(ns)
    }

    /// Run chemistry for `cell` now: integrate, apply to the grid, and
    /// return the output record plus its simulated PHREEQC cost.
    fn simulate_cell(&mut self, cell: usize) -> ([f64; N_OUT], u64) {
        let row = self.grid.row(cell, self.cfg.dt);
        let rec = integrate_cell(&row);
        let cost = self.cfg.cost.cost_ns(&row, &rec);
        self.grid.apply(cell, &rec);
        self.chem_cells += 1;
        (rec, cost)
    }
}

impl Workload for PoetWorkload {
    type Sm = ReplSm;

    fn next(&mut self, rank: u32, lane: u32, now: Time) -> WorkItem<ReplSm> {
        let r = rank as usize;
        let ctx = self.ctx(rank, lane);

        // A completed Think is signalled by this lane asking again while
        // still holding an Overhead/Compute job.
        match std::mem::replace(&mut self.lane_job[ctx], LaneJob::Idle) {
            LaneJob::Overhead => {
                self.cur[r].overhead_inflight = false;
                self.cur[r].overhead_done = true;
            }
            LaneJob::Compute { write } => {
                self.cur[r].computing = false;
                if let Some((key, rec)) = write {
                    // chemistry cost charged: store the result (the miss
                    // write of the batched pass).  With replication the
                    // k-1 copies queue for sibling lanes so the fan-out
                    // rides the same pipelined epoch (DESIGN.md §9).
                    let dcfg =
                        self.dht.clone().expect("dht in miss write");
                    let val = pack_row(&rec);
                    for rep in 1..dcfg.addressing.replicas() {
                        self.cur[r].write_q.push_back(DhtSm::write_at(
                            dcfg.variant,
                            &dcfg,
                            &key,
                            &val,
                            rep,
                        ));
                    }
                    let sm = DhtSm::write(dcfg.variant, &dcfg, &key, &val);
                    self.lane_job[ctx] = LaneJob::Write { replica: false };
                    self.cur[r].writes_inflight += 1;
                    self.poll_ns[ctx] = LANE_POLL_NS;
                    return WorkItem::Op(ReplSm::Op(sm));
                }
            }
            LaneJob::Idle => {}
            LaneJob::Read { .. } | LaneJob::Write { .. } => {
                unreachable!("op jobs are cleared in on_complete")
            }
        }

        if self.cur[r].step >= self.cfg.steps {
            return WorkItem::Finished;
        }

        // step advance (first lane to wake after the end-of-step barrier)
        if self.cur[r].step_complete {
            self.cur[r].step_complete = false;
            self.cur[r].step += 1;
            self.cur[r].next_cell = 0;
            self.cur[r].overhead_done = false;
            if self.cur[r].step >= self.cfg.steps {
                return WorkItem::Finished;
            }
        }

        // per-step serial overhead (transport + collective sync) first
        if !self.cur[r].overhead_done {
            if self.cur[r].overhead_inflight {
                return self.poll(ctx);
            }
            let step = self.cur[r].step;
            self.apply_transport(step);
            self.cur[r].overhead_inflight = true;
            self.lane_job[ctx] = LaneJob::Overhead;
            self.poll_ns[ctx] = LANE_POLL_NS;
            let (lo, hi) = self.ranges[r];
            let cells = (hi - lo) as u64;
            let sync = (self.cfg.step_sync_ns as f64
                * (self.cfg.nranks.max(2) as f64).log2())
                as u64;
            return WorkItem::Think(
                self.cfg.step_overhead_ns
                    + sync
                    + cells * self.cfg.transport_ns_per_cell,
            );
        }

        // replica fan-out writes queued by completed chemistry first
        // (they are paid-for results; draining them promptly keeps the
        // copies close behind their primaries)
        if let Some(sm) = self.cur[r].write_q.pop_front() {
            self.cur[r].writes_inflight += 1;
            self.lane_job[ctx] = LaneJob::Write { replica: true };
            self.poll_ns[ctx] = LANE_POLL_NS;
            return WorkItem::Op(ReplSm::Op(sm));
        }

        // chemistry for queued misses (one CPU per rank: serialized)
        if !self.cur[r].computing {
            if let Some((cell, key)) = self.cur[r].compute_q.pop_front() {
                self.cur[r].computing = true;
                let (rec, cost) = self.simulate_cell(cell);
                self.lane_job[ctx] = LaneJob::Compute {
                    write: self.dht.as_ref().map(|_| (key, rec)),
                };
                self.poll_ns[ctx] = LANE_POLL_NS;
                return WorkItem::Think(cost);
            }
        }

        // issue the next cell
        let (lo, hi) = self.ranges[r];
        if lo + self.cur[r].next_cell < hi {
            // reference runs simulate cells one at a time (one CPU per
            // rank); do not consume a cell while another lane computes
            if self.dht.is_none() && self.cur[r].computing {
                return self.poll(ctx);
            }
            let cell = lo + self.cur[r].next_cell;
            self.cur[r].next_cell += 1;
            self.poll_ns[ctx] = LANE_POLL_NS;
            match &self.dht {
                None => {
                    self.cur[r].computing = true;
                    let (_rec, cost) = self.simulate_cell(cell);
                    self.lane_job[ctx] = LaneJob::Compute { write: None };
                    return WorkItem::Think(cost);
                }
                Some(dcfg) => {
                    let row = self.grid.row(cell, self.cfg.dt);
                    let key = cell_key(&row, self.cfg.digits);
                    let sm = if dcfg.addressing.replicas() > 1 {
                        // degraded-read failover: skip ranks the fault
                        // plan has killed by `now`, fall through on miss
                        ReplSm::Read(ReplReadSm::new(dcfg, None, &key, |t| {
                            self.rank_dead(t, now)
                        }))
                    } else {
                        ReplSm::Op(DhtSm::read(dcfg.variant, dcfg, &key))
                    };
                    self.lane_job[ctx] = LaneJob::Read { cell, key };
                    self.cur[r].reads_inflight += 1;
                    return WorkItem::Op(sm);
                }
            }
        }

        // no new cells: wait for in-flight work, or end the step
        if !self.cur[r].drained() {
            return self.poll(ctx);
        }
        self.poll_ns[ctx] = LANE_POLL_NS;
        self.cur[r].step_complete = true;
        WorkItem::Barrier
    }

    fn on_complete(
        &mut self,
        rank: u32,
        lane: u32,
        _now: Time,
        _latency: Time,
        out: ReplOut,
    ) {
        let r = rank as usize;
        let ctx = self.ctx(rank, lane);
        match std::mem::replace(&mut self.lane_job[ctx], LaneJob::Idle) {
            LaneJob::Read { cell, key } => {
                self.cur[r].reads_inflight -= 1;
                // failover/divergence bookkeeping + the plain record
                self.stats.record_failover(&out);
                let step = self.cur[r].step.min(self.step_hits.len() - 1);
                match out.out.outcome {
                    DhtOutcome::ReadHit(v) => {
                        self.hits += 1;
                        self.step_hits[step].0 += 1;
                        self.grid.apply(cell, &unpack_value(&v));
                    }
                    DhtOutcome::ReadMiss | DhtOutcome::ReadCorrupt => {
                        self.misses += 1;
                        self.step_hits[step].1 += 1;
                        self.cur[r].compute_q.push_back((cell, key));
                    }
                    other => unreachable!("read completed with {other:?}"),
                }
            }
            LaneJob::Write { replica } => {
                self.cur[r].writes_inflight -= 1;
                if replica {
                    self.stats.record_replica_write(&out.out);
                } else {
                    self.stats.record(&out.out);
                }
                debug_assert!(matches!(
                    out.out.outcome,
                    DhtOutcome::WriteFresh
                        | DhtOutcome::WriteUpdate
                        | DhtOutcome::WriteEvict
                ));
            }
            _ => unreachable!("op completion without an op job"),
        }
    }
}

/// Run one DES POET configuration.
pub fn run_poet_des(cfg: PoetDesCfg, net_cfg: NetConfig) -> PoetDesResult {
    let nranks = cfg.nranks;
    let win_bytes = cfg.win_bytes;
    let lanes = cfg.pipeline.max(1);
    let fault = cfg
        .kill_rank_at
        .map(|(rank, at)| FaultPlan::default().kill_rank_at(rank, at));
    let net = Network::new(net_cfg, nranks);
    let mut cluster = SimCluster::with_pipeline(
        PoetWorkload::new(cfg),
        net,
        nranks,
        win_bytes,
        lanes,
    );
    if let Some(plan) = fault {
        cluster.set_fault_plan(plan);
    }
    let sim = cluster.run();
    let w = &mut cluster.workload;
    PoetDesResult {
        runtime_s: sim.duration as f64 / 1e9,
        chem_cells: w.chem_cells,
        hits: w.hits,
        misses: w.misses,
        dht: std::mem::take(&mut w.stats),
        max_dolomite: w.grid.max_dolomite(),
        step_hits: std::mem::take(&mut w.step_hits),
        sim,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(nranks: u32, variant: Option<Variant>) -> PoetDesCfg {
        let mut c = PoetDesCfg::scaled(nranks, variant);
        c.ny = 12;
        c.nx = 24;
        c.steps = 12;
        c.inj_rows = 3;
        c
    }


    /// Calibration probe:
    /// `cargo test --release poet_fig7_probe -- --ignored --nocapture`
    #[test]
    #[ignore]
    fn poet_fig7_probe() {
        for nranks in [128u32, 640] {
            let t0 = std::time::Instant::now();
            let refr = run_poet_des(PoetDesCfg::scaled(nranks, None),
                                    NetConfig::pik_ndr());
            let t1 = std::time::Instant::now();
            let lf = run_poet_des(
                PoetDesCfg::scaled(nranks, Some(Variant::LockFree)),
                NetConfig::pik_ndr());
            println!(
                "n={nranks}: ref {:.1}s (wall {:.1}s) | lock-free {:.1}s \
                 (wall {:.1}s) hit {:.3} mism {} gain {:.1}%",
                refr.runtime_s, (t1 - t0).as_secs_f64(),
                lf.runtime_s, t1.elapsed().as_secs_f64(),
                lf.hit_rate(), lf.dht.mismatches,
                100.0 * (1.0 - lf.runtime_s / refr.runtime_s));
        }
    }

    #[test]
    fn reference_simulates_every_cell() {
        let cfg = tiny(8, None);
        let cells = cfg.ny * cfg.nx;
        let steps = cfg.steps;
        let res = run_poet_des(cfg, NetConfig::pik_ndr());
        assert_eq!(res.chem_cells, (cells * steps) as u64);
        assert_eq!(res.hits, 0);
        assert!(res.runtime_s > 0.0);
    }

    #[test]
    fn dht_run_hits_and_is_faster() {
        let refr = run_poet_des(tiny(8, None), NetConfig::pik_ndr());
        let lf = run_poet_des(
            tiny(8, Some(Variant::LockFree)),
            NetConfig::pik_ndr(),
        );
        assert!(lf.hit_rate() > 0.5, "hit rate {}", lf.hit_rate());
        assert!(lf.chem_cells < refr.chem_cells / 2);
        assert!(
            lf.runtime_s < refr.runtime_s,
            "lock-free {} vs ref {}",
            lf.runtime_s,
            refr.runtime_s
        );
        // same physics emerges
        assert!(lf.max_dolomite > 0.0);
    }

    #[test]
    fn pipelined_poet_same_physics_faster_lookups() {
        let mut base = tiny(8, Some(Variant::LockFree));
        base.steps = 10;
        let d1 = run_poet_des(base.clone(), NetConfig::pik_ndr());
        let mut piped = base.clone();
        piped.pipeline = 8;
        let d8 = run_poet_des(piped, NetConfig::pik_ndr());
        // identical coupled physics: every cell is read exactly once per
        // step regardless of pipelining
        assert_eq!(
            d1.hits + d1.misses,
            d8.hits + d8.misses,
            "same number of surrogate lookups"
        );
        assert!(d8.hit_rate() > 0.4, "hit rate {}", d8.hit_rate());
        assert!(d8.max_dolomite > 0.0);
        // overlapping the per-cell DHT reads must not be slower
        assert!(
            d8.runtime_s <= d1.runtime_s * 1.05,
            "pipelined {} vs blocking {}",
            d8.runtime_s,
            d1.runtime_s
        );
    }

    #[test]
    fn replicated_poet_same_lookups_and_physics() {
        // k = 2 must not change the coupled physics or the number of
        // surrogate lookups — only add the fan-out copies
        let base = tiny(8, Some(Variant::LockFree));
        let d1 = run_poet_des(base.clone(), NetConfig::pik_ndr());
        let mut repl = base.clone();
        repl.replicas = 2;
        repl.pipeline = 4;
        let d2 = run_poet_des(repl, NetConfig::pik_ndr());
        assert_eq!(
            d1.hits + d1.misses,
            d2.hits + d2.misses,
            "same number of surrogate lookups"
        );
        assert!(d2.dht.replica_writes > 0, "copies fanned out");
        assert_eq!(
            d2.dht.replica_writes, d2.dht.writes,
            "exactly one copy per primary write at k=2"
        );
        assert!(d2.hit_rate() > 0.4, "hit rate {}", d2.hit_rate());
        assert!(d2.max_dolomite > 0.0);
        // per-step trajectory accounts for every lookup
        let (h, m) = d2
            .step_hits
            .iter()
            .fold((0u64, 0u64), |(a, b), (x, y)| (a + x, b + y));
        assert_eq!((h, m), (d2.hits, d2.misses));
    }

    #[test]
    fn des_grid_matches_threaded_reference() {
        // the DES reference and the threaded reference run identical
        // physics (same native chemistry + transport)
        let cfg = tiny(4, None);
        let (ny, nx, steps, inj) = (cfg.ny, cfg.nx, cfg.steps, cfg.inj_rows);
        let net = Network::new(NetConfig::pik_ndr(), cfg.nranks);
        let mut cluster = SimCluster::new(
            PoetWorkload::new(cfg.clone()),
            net,
            cfg.nranks,
            cfg.win_bytes,
        );
        cluster.run();

        let mut pcfg = crate::poet::PoetConfig::small();
        pcfg.ny = ny;
        pcfg.nx = nx;
        pcfg.steps = steps;
        pcfg.inj_rows = inj;
        pcfg.dt = cfg.dt;
        pcfg.cf = cfg.cf;
        pcfg.workers = 1;
        let mut drv = crate::poet::PoetDriver::with_default_waters(
            pcfg,
            std::sync::Arc::new(crate::poet::NativeChemistry),
        );
        drv.run_reference();
        for (a, b) in cluster
            .workload
            .grid
            .solutes
            .iter()
            .zip(drv.grid.solutes.iter())
        {
            assert!((a - b).abs() < 1e-14, "{a} vs {b}");
        }
    }
}
