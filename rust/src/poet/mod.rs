//! POET — the coupled reactive transport simulator (paper §5.4).
//!
//! POET couples solute advection with kinetic calcite/dolomite
//! geochemistry on a 2-D grid and caches chemistry results in the DHT:
//! per cell and time step, the rounded chemical state is the 80-byte key
//! and the full simulation result the 104-byte value; a hit replaces the
//! expensive geochemistry call (PHREEQC in the paper, the L1/L2 JAX +
//! Pallas engine here).
//!
//! Two execution modes (DESIGN.md §2):
//!
//! * **real/threaded** ([`driver`]) — actual wall-clock runs on this
//!   machine: PJRT chemistry via the AOT artifacts (or the bit-identical
//!   [`chemistry::NativeChemistry`]), shm-backend DHT, worker threads.
//!   Used by the end-to-end example and the integration tests.
//! * **DES** ([`desmodel`]) — the *same coupled simulation* (real grid,
//!   real keys, real DHT protocol over real window memory) driven inside
//!   the discrete-event cluster with a calibrated chemistry *time* model,
//!   which is how Fig. 7 / Tables 3–4 are reproduced at 128–640 ranks.

pub mod chemistry;
pub mod desmodel;
pub mod driver;
pub mod grid;
pub mod key;
pub mod transport;

pub use chemistry::{ChemCost, Chemistry, NativeChemistry, PjrtChemistry};
pub use driver::{PoetConfig, PoetDriver, PoetRunStats};
pub use grid::GridState;
pub use key::{
    cell_key, ladder_key, ladder_rel_err, pack_row, round_sig,
    row_is_finite, unpack_value, LadderCfg,
};
