//! Native upwind advection — the Rust mirror of
//! `python/compile/kernels/advection.py` (validated against the AOT golden
//! vectors in the integration tests).
//!
//! First-order explicit upwind with constant fluxes (vx, vy >= 0); the
//! west ghost column is injection water for the first `inj_rows` rows and
//! background water elsewhere; the north ghost row is background.

use super::chemistry::N_SOLUTES;

/// Advect the solute planes one step in place.
///
/// `c` is `[ns][ny][nx]` row-major (species-major), `inflow` is
/// `[ns][2]` = [injection, background] per species.
pub fn advect_step(
    c: &mut [f64],
    scratch: &mut Vec<f64>,
    ny: usize,
    nx: usize,
    inflow: &[f64],
    cf: [f64; 2],
    inj_rows: usize,
) {
    let ns = N_SOLUTES;
    assert_eq!(c.len(), ns * ny * nx);
    assert_eq!(inflow.len(), ns * 2);
    let (cfx, cfy) = (cf[0], cf[1]);
    scratch.clear();
    scratch.extend_from_slice(c);
    let old = &scratch[..];
    for s in 0..ns {
        let inj = inflow[s * 2];
        let bg = inflow[s * 2 + 1];
        let plane = s * ny * nx;
        for y in 0..ny {
            let west_ghost = if y < inj_rows { inj } else { bg };
            let row = plane + y * nx;
            for x in 0..nx {
                let v = old[row + x];
                let west = if x == 0 { west_ghost } else { old[row + x - 1] };
                let north = if y == 0 { bg } else { old[row - nx + x] };
                c[row + x] = v - cfx * (v - west) - cfy * (v - north);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poet::chemistry::default_waters;

    fn uniform_grid(ny: usize, nx: usize, vals: &[f64]) -> Vec<f64> {
        let mut c = Vec::with_capacity(N_SOLUTES * ny * nx);
        for s in 0..N_SOLUTES {
            c.extend(std::iter::repeat(vals[s]).take(ny * nx));
        }
        c
    }

    fn inflow_of(inj: &[f64], bg: &[f64]) -> Vec<f64> {
        let mut v = Vec::new();
        for s in 0..N_SOLUTES {
            v.push(inj[s]);
            v.push(bg[s]);
        }
        v
    }

    #[test]
    fn stationary_for_matching_inflow() {
        let (bg, _, _) = default_waters();
        let mut c = uniform_grid(8, 12, &bg);
        let orig = c.clone();
        let inflow = inflow_of(&bg, &bg);
        let mut scratch = Vec::new();
        advect_step(&mut c, &mut scratch, 8, 12, &inflow, [0.4, 0.2], 3);
        for (a, b) in c.iter().zip(orig.iter()) {
            assert!((a - b).abs() < 1e-16);
        }
    }

    #[test]
    fn zero_cfl_identity() {
        let (bg, inj, _) = default_waters();
        let mut c = uniform_grid(6, 6, &bg);
        let orig = c.clone();
        let inflow = inflow_of(&inj, &bg);
        let mut scratch = Vec::new();
        advect_step(&mut c, &mut scratch, 6, 6, &inflow, [0.0, 0.0], 2);
        assert_eq!(c, orig);
    }

    #[test]
    fn injection_enters_top_left() {
        let (bg, inj, _) = default_waters();
        let (ny, nx) = (8usize, 16usize);
        let mut c = uniform_grid(ny, nx, &bg);
        let inflow = inflow_of(&inj, &bg);
        let mut scratch = Vec::new();
        for _ in 0..6 {
            advect_step(&mut c, &mut scratch, ny, nx, &inflow, [0.5, 0.0], 3);
        }
        // Mg (species 1) rises in the injection rows near the inlet
        let mg = |y: usize, x: usize| c[ny * nx + y * nx + x];
        assert!(mg(0, 0) > 100.0 * bg[1]);
        assert!(mg(2, 0) > 100.0 * bg[1]);
        // below the injection stream: untouched background
        assert!((mg(5, 0) - bg[1]).abs() < 1e-15);
        // far downstream: untouched
        assert!((mg(0, 12) - bg[1]).abs() < 1e-15);
    }

    #[test]
    fn monotone_no_new_extrema() {
        let (bg, inj, _) = default_waters();
        let ny = 10;
        let nx = 10;
        let mut c = uniform_grid(ny, nx, &bg);
        // perturb a blob
        for y in 3..6 {
            for x in 3..6 {
                c[ny * nx + y * nx + x] = 5e-3;
            }
        }
        let inflow = inflow_of(&inj, &bg);
        let lo = c.iter().cloned().fold(f64::INFINITY, f64::min).min(
            inflow.iter().cloned().fold(f64::INFINITY, f64::min));
        let hi = c.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(
            inflow.iter().cloned().fold(f64::NEG_INFINITY, f64::max));
        let mut scratch = Vec::new();
        for _ in 0..20 {
            advect_step(&mut c, &mut scratch, ny, nx, &inflow, [0.4, 0.3], 4);
        }
        for v in &c {
            assert!(*v >= lo - 1e-15 && *v <= hi + 1e-15);
        }
    }
}
