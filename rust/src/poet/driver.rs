//! Threaded (real wall-clock) POET driver.
//!
//! This is the application a user of the library actually runs: the grid
//! is advected (native transport, bit-identical to the AOT artifact),
//! chemistry goes through a [`Chemistry`] engine (PJRT artifacts or the
//! native mirror), and an optional DHT serves as the surrogate cache
//! exactly as in the paper: round state -> key -> one pipelined
//! `DHT_read_batch` over the worker's whole cell range; misses are
//! simulated and stored with one `DHT_write_batch` pass after chemistry
//! (DESIGN.md §3).
//!
//! Worker threads own disjoint cell ranges ("ranks"); each holds its own
//! [`Dht`] handle onto the shared shm cluster, mirroring MPI ranks.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::dht::{Dht, DhtStats, EvictPolicy, Variant};

use super::chemistry::{Chemistry, N_IN, N_OUT};
use super::grid::GridState;
use super::key::{
    fold_tenant, ladder_key, pack_row, row_is_finite, unpack_value,
    LadderCfg,
};
use super::transport;

/// Configuration of a POET run.
#[derive(Clone, Debug)]
pub struct PoetConfig {
    pub ny: usize,
    pub nx: usize,
    pub steps: usize,
    /// Transport time step [s] (also part of the chemistry key).
    pub dt: f64,
    /// Courant numbers [cfx, cfy].
    pub cf: [f64; 2],
    /// Rows (from the top) fed by injection water.
    pub inj_rows: usize,
    /// Significant digits for surrogate keys (§5.4's accuracy knob).
    pub digits: u32,
    /// Extra coarser key-ladder levels probed on a fine-level miss
    /// (DESIGN.md §10; 0 = the paper's exact-match lookup).  Level `l`
    /// re-rounds to `digits - l` significant digits; accepted hits
    /// back-fill the fine level.
    pub ladder: u32,
    /// Max per-species relative deviation an accepted coarse-level hit
    /// may introduce (the ladder's acceptance tolerance).
    pub ladder_rel_tol: f64,
    /// Rank-local L1 read-through cache budget per worker, bytes
    /// (DESIGN.md §10; 0 = off).
    pub l1_bytes: usize,
    /// Worker threads ("ranks").
    pub workers: usize,
    /// DHT window bytes per worker (when a DHT is used).
    pub win_bytes: usize,
    /// Repeat each chemistry batch this many times (engine stress knob).
    pub chem_repeat: usize,
    /// Extra CPU time per simulated cell, µs.  Our Pallas/JAX chemistry
    /// runs ~100x faster per cell than the paper's PHREEQC (a win in
    /// itself); this knob emulates a full-physics solver's per-cell cost
    /// so the surrogate cache operates in the paper's regime (paper:
    /// ~206 µs/cell).  Default 0 = off.
    pub chem_extra_us: f64,
    /// In-flight DHT ops per batched surrogate lookup/store pass
    /// (pipeline depth of `read_batch`/`write_batch`; DESIGN.md §3).
    pub pipeline: usize,
    /// k-way DHT replication factor (DESIGN.md §9; 1 = the paper's
    /// single-owner placement, clamped to the worker count).
    pub replicas: u32,
    /// Mid-run elastic resize (DESIGN.md §8): before this step, grow (or
    /// shrink) the DHT to `resize_factor` x its per-rank bucket count.
    /// Demonstrates online hit-rate recovery for an undersized table
    /// (CLI: `--resize-at-iter N --resize-factor F`).
    pub resize_at_step: Option<usize>,
    /// Capacity factor applied at `resize_at_step`.
    pub resize_factor: f64,
    /// Online replica repair (DESIGN.md §11): when a rank dies, every
    /// live worker re-homes the shard copies it still holds onto the
    /// next live successors, piggybacked on its normal batched passes.
    pub repair: bool,
    /// Chaos schedule: before step `.0`, mark worker rank `.1` failed on
    /// the shared cluster (its shard reads as lost; degraded-mode ops).
    pub kill_at_step: Option<(usize, u32)>,
    /// Before step `.0`, clear the failed mark on rank `.1` — the rank
    /// rejoins with whatever its window still holds (benign for the
    /// surrogate workload: values are pure functions of their keys).
    pub revive_at_step: Option<(usize, u32)>,
    /// Concurrent tenant namespaces over the one shared cache (DESIGN.md
    /// §14): workers are block-partitioned across `tenants`, each keying
    /// its cells under its own [`fold_tenant`] namespace via a
    /// tenant-scoped [`Dht::tenant`] view.  Clamped to the worker count;
    /// 1 = the anonymous single-tenant run (bit-identical keys/records).
    pub tenants: u32,
    /// Full-candidate-set write behavior of the shared cache (DESIGN.md
    /// §14).  `Drop` keeps the pre-tenant bit-identical tables.
    pub evict: EvictPolicy,
}

impl PoetConfig {
    pub fn small() -> Self {
        Self {
            ny: 24,
            nx: 72,
            steps: 100,
            dt: 2000.0,
            cf: [0.4, 0.1],
            inj_rows: 5,
            digits: 4,
            ladder: 0,
            ladder_rel_tol: 5e-3,
            l1_bytes: 0,
            workers: 2,
            win_bytes: 4 << 20,
            chem_repeat: 1,
            chem_extra_us: 0.0,
            pipeline: crate::dht::front::DEFAULT_PIPELINE,
            replicas: 1,
            resize_at_step: None,
            resize_factor: 2.0,
            repair: false,
            kill_at_step: None,
            revive_at_step: None,
            tenants: 1,
            evict: EvictPolicy::Drop,
        }
    }
}

/// Aggregated results of a run.
#[derive(Clone, Debug, Default)]
pub struct PoetRunStats {
    pub steps: usize,
    pub wall_s: f64,
    /// Cells sent through the chemistry engine (misses + reference cells).
    pub chem_cells: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub dht: DhtStats,
    /// Per-step (hits, misses) — the hit-rate trajectory a mid-run
    /// resize is judged by (empty for reference runs).
    pub step_hits: Vec<(u64, u64)>,
    /// Per-tenant (hits, misses) of the surrogate lookups (DESIGN.md
    /// §14; empty for reference runs, one entry single-tenant).
    pub tenant_hits: Vec<(u64, u64)>,
    /// Final-state diagnostics.
    pub max_dolomite: f64,
    pub inlet_calcite: f64,
}

impl PoetRunStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Mean hit rate over the step range `[lo, hi)` (clamped).
    pub fn hit_rate_over(&self, lo: usize, hi: usize) -> f64 {
        let hi = hi.min(self.step_hits.len());
        let lo = lo.min(hi);
        let (h, m) = self.step_hits[lo..hi]
            .iter()
            .fold((0u64, 0u64), |(h, m), (sh, sm)| (h + sh, m + sm));
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Hit rate of tenant `t`'s surrogate lookups.
    pub fn tenant_hit_rate(&self, t: usize) -> f64 {
        match self.tenant_hits.get(t) {
            Some(&(h, m)) if h + m > 0 => h as f64 / (h + m) as f64,
            _ => 0.0,
        }
    }

    /// Jain fairness index over the tenants' hit rates (1.0 = every
    /// tenant gets the same service from the shared cache; DESIGN.md
    /// §14).  Tenants that issued no lookups are excluded.
    pub fn fairness(&self) -> f64 {
        let rates: Vec<f64> = self
            .tenant_hits
            .iter()
            .filter(|(h, m)| h + m > 0)
            .map(|&(h, m)| h as f64 / (h + m) as f64)
            .collect();
        crate::dht::stats::jain_fairness(&rates)
    }
}

/// The coupled simulator.
pub struct PoetDriver {
    pub cfg: PoetConfig,
    pub grid: GridState,
    inflow: Vec<f64>,
    chemistry: Arc<dyn Chemistry>,
}

impl PoetDriver {
    /// Build with explicit waters (`inflow` = per-species [inj, bg]).
    pub fn new(
        cfg: PoetConfig,
        chemistry: Arc<dyn Chemistry>,
        background: &[f64],
        injection: &[f64],
        minerals0: &[f64],
    ) -> Self {
        let grid = GridState::new(cfg.ny, cfg.nx, background, minerals0);
        let mut inflow = Vec::with_capacity(background.len() * 2);
        for s in 0..background.len() {
            inflow.push(injection[s]);
            inflow.push(background[s]);
        }
        Self { cfg, grid, inflow, chemistry }
    }

    /// Build with the default waters of the model.
    pub fn with_default_waters(cfg: PoetConfig, chemistry: Arc<dyn Chemistry>) -> Self {
        let (bg, inj, min0) = super::chemistry::default_waters();
        Self::new(cfg, chemistry, &bg, &inj, &min0)
    }

    /// Run without a DHT (the paper's reference configuration).
    pub fn run_reference(&mut self) -> PoetRunStats {
        self.run_inner(None)
    }

    /// Run with a DHT surrogate cache of the given variant.
    pub fn run_with_dht(&mut self, variant: Variant) -> PoetRunStats {
        let mut handles =
            Dht::create_poet(variant, self.cfg.workers as u32, self.cfg.win_bytes);
        for h in &mut handles {
            h.set_pipeline(self.cfg.pipeline);
            h.set_replicas(self.cfg.replicas);
            h.set_l1_bytes(self.cfg.l1_bytes);
            h.set_repair(self.cfg.repair);
            h.set_evict(self.cfg.evict);
        }
        // multi-tenant sharding (DESIGN.md §14): block-partition the
        // workers across tenants and swap each worker's handle for the
        // tenant-scoped view (shared windows, per-tenant stamps/stats);
        // tenants == 1 keeps the original handles untouched — the
        // bit-identical anonymous path
        let tenants =
            self.cfg.tenants.clamp(1, self.cfg.workers.max(1) as u32);
        if tenants > 1 {
            let n = handles.len();
            handles = handles
                .into_iter()
                .enumerate()
                .map(|(w, h)| h.tenant((w * tenants as usize / n) as u32))
                .collect();
        }
        self.run_inner(Some(handles))
    }

    fn run_inner(&mut self, dht: Option<Vec<Dht>>) -> PoetRunStats {
        let t0 = Instant::now();
        let cfg = self.cfg.clone();
        let cells = self.grid.cells();
        let nworkers = cfg.workers.max(1);
        let mut scratch = Vec::new();
        let mut stats = PoetRunStats { steps: cfg.steps, ..Default::default() };

        // per-worker DHT handles (None for the reference run)
        let mut handles: Vec<Option<Dht>> = match dht {
            Some(hs) => hs.into_iter().map(Some).collect(),
            None => (0..nworkers).map(|_| None).collect(),
        };
        let with_dht = handles.iter().any(Option::is_some);
        // per-worker tenant ids for the per-tenant hit ledger (all 0 in
        // single-tenant runs; the ledger stays empty on reference runs)
        let tenant_of: Vec<usize> = handles
            .iter()
            .map(|h| h.as_ref().map_or(0, |x| x.tenant_id() as usize))
            .collect();
        if with_dht {
            let nt = tenant_of.iter().copied().max().unwrap_or(0) + 1;
            stats.tenant_hits = vec![(0, 0); nt];
        }

        // cell ranges per worker (contiguous blocks, like POET's
        // cell-wise distribution over MPI ranks)
        let ranges: Vec<(usize, usize)> = (0..nworkers)
            .map(|w| (w * cells / nworkers, (w + 1) * cells / nworkers))
            .collect();

        for step in 0..cfg.steps {
            // mid-run elastic resize: one handle initiates; every worker
            // cooperatively migrates its own shard piggybacked on its
            // subsequent batched lookups/stores (DESIGN.md §8)
            if cfg.resize_at_step == Some(step) {
                if let Some(h) = handles.iter_mut().flatten().next() {
                    let cur = h.buckets_per_rank();
                    let target = ((cur as f64 * cfg.resize_factor).ceil()
                        as u64)
                        .max(1);
                    h.resize(target).expect("mid-run resize");
                }
            }
            // chaos schedule: flip the shared failed-rank mask before
            // the step; the health generation bump arms a repair pass on
            // every live handle (piggybacked on the batched passes)
            if cfg.kill_at_step.map(|(s, _)| s) == Some(step) {
                let r = cfg.kill_at_step.unwrap().1;
                if let Some(h) = handles.iter_mut().flatten().next() {
                    h.set_rank_failed(r, true);
                }
            }
            if cfg.revive_at_step.map(|(s, _)| s) == Some(step) {
                let r = cfg.revive_at_step.unwrap().1;
                if let Some(h) = handles.iter_mut().flatten().next() {
                    h.set_rank_failed(r, false);
                }
            }
            transport::advect_step(
                &mut self.grid.solutes,
                &mut scratch,
                cfg.ny,
                cfg.nx,
                &self.inflow,
                cfg.cf,
                cfg.inj_rows,
            );

            // chemistry phase: workers process their cells in parallel
            let grid = &self.grid;
            let chem = &self.chemistry;
            let cfg_ref = &cfg;
            let results: Vec<WorkerOut> = std::thread::scope(|s| {
                let mut joins = Vec::new();
                for (w, h) in handles.iter_mut().enumerate() {
                    let (lo, hi) = ranges[w];
                    joins.push(s.spawn(move || {
                        worker_chunk(grid, chem.as_ref(), h.as_mut(), lo, hi,
                                     cfg_ref)
                    }));
                }
                joins.into_iter().map(|j| j.join().expect("worker")).collect()
            });

            let mut step_h = 0u64;
            let mut step_m = 0u64;
            for (w, out) in results.into_iter().enumerate() {
                step_h += out.hits;
                step_m += out.misses;
                stats.cache_hits += out.hits;
                stats.cache_misses += out.misses;
                stats.chem_cells += out.chem_cells;
                if with_dht {
                    let t = &mut stats.tenant_hits[tenant_of[w]];
                    t.0 += out.hits;
                    t.1 += out.misses;
                }
                for (cell, rec) in out.updates {
                    self.grid.apply(cell, &rec);
                }
            }
            if with_dht {
                stats.step_hits.push((step_h, step_m));
            }
        }

        for h in handles.iter_mut().flatten() {
            stats.dht.merge(&h.take_stats());
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        stats.max_dolomite = self.grid.max_dolomite();
        stats.inlet_calcite = self.grid.mean_calcite(
            0,
            cfg.inj_rows.min(cfg.ny),
            0,
            (cfg.nx / 10).max(1),
        );
        stats
    }
}

struct WorkerOut {
    updates: Vec<(usize, [f64; N_OUT])>,
    hits: u64,
    misses: u64,
    chem_cells: u64,
}

fn worker_chunk(
    grid: &GridState,
    chem: &dyn Chemistry,
    mut dht: Option<&mut Dht>,
    lo: usize,
    hi: usize,
    cfg: &PoetConfig,
) -> WorkerOut {
    let (dt, chem_repeat) = (cfg.dt, cfg.chem_repeat);
    let lcfg = LadderCfg {
        digits: cfg.digits,
        levels: cfg.ladder,
        rel_tol: cfg.ladder_rel_tol,
    };
    // this worker's tenant namespace (DESIGN.md §14): every key — fine
    // and coarse — is folded to the handle's tenant, so equal chemistry
    // states collide within a tenant and never across tenants.  Tenant 0
    // (and the reference run) keys are byte-identical to the
    // single-tenant path.
    let tenant = dht.as_deref().map_or(0, |d| d.tenant_id());
    let tkey = |mut k: Vec<u8>| {
        if tenant != 0 {
            fold_tenant(&mut k, tenant);
        }
        k
    };
    let mut out = WorkerOut {
        updates: Vec::with_capacity(hi - lo),
        hits: 0,
        misses: 0,
        chem_cells: 0,
    };
    // batch of cells that must be simulated (misses / reference); the
    // key is None for non-finite rows, which bypass the DHT entirely
    // (simulated but never keyed or stored)
    let mut miss_cells: Vec<usize> = Vec::new();
    let mut miss_keys: Vec<Option<Vec<u8>>> = Vec::new();
    let mut miss_rows: Vec<f64> = Vec::new();
    // accepted coarse-level hits back-fill the fine level (one write
    // pass together with the post-chemistry stores)
    let mut store_keys: Vec<Vec<u8>> = Vec::new();
    let mut store_vals: Vec<Vec<u8>> = Vec::new();

    if let Some(d) = dht.as_deref_mut() {
        // ONE pipelined surrogate lookup for the whole cell range (the
        // paper's access pattern: every cell's state is keyed per round)
        let mut rows: Vec<[f64; N_IN]> = Vec::with_capacity(hi - lo);
        let mut fine_cells: Vec<usize> = Vec::with_capacity(hi - lo);
        let mut fine_keys: Vec<Vec<u8>> = Vec::with_capacity(hi - lo);
        for cell in lo..hi {
            let row = grid.row(cell, dt);
            rows.push(row);
            if row_is_finite(&row) {
                fine_cells.push(cell);
                fine_keys.push(tkey(ladder_key(&row, &lcfg, 0)));
            } else {
                // no key is sound for a non-finite state: straight to
                // chemistry, counted, never cached (DESIGN.md §10)
                d.note_nonfinite_skip();
                out.misses += 1;
                miss_cells.push(cell);
                miss_keys.push(None);
                miss_rows.extend_from_slice(&row);
            }
        }
        let values = d.read_batch(&fine_keys);
        // fine-level misses feed the ladder epoch (cell, fine key);
        // coarse keys shared by several pending cells (the ladder's
        // whole point: neighbors coarsen to the same cell) are probed
        // once and fanned back out to every consumer
        let mut pend_cells: Vec<usize> = Vec::new();
        let mut pend_keys: Vec<Vec<u8>> = Vec::new();
        let mut probe_keys: Vec<Vec<u8>> = Vec::new();
        let mut probe_consumers: Vec<Vec<(usize, u32, f64)>> = Vec::new();
        let mut probe_index: HashMap<Vec<u8>, usize> = HashMap::new();
        for ((cell, key), val) in fine_cells
            .into_iter()
            .zip(fine_keys.into_iter())
            .zip(values.into_iter())
        {
            match val {
                Some(v) => {
                    out.hits += 1;
                    d.note_ladder_hit(0, 0.0);
                    out.updates.push((cell, unpack_value(&v)));
                }
                None if lcfg.levels == 0 => {
                    out.misses += 1;
                    miss_cells.push(cell);
                    miss_rows.extend_from_slice(&rows[cell - lo]);
                    miss_keys.push(Some(key));
                }
                None => {
                    // ladder candidates: only levels whose rounding
                    // stays inside the acceptance tolerance are probed
                    let pi = pend_cells.len();
                    for (level, pkey, err) in lcfg.probes(&rows[cell - lo]) {
                        let pkey = tkey(pkey);
                        let slot = match probe_index.get(&pkey) {
                            Some(&s) => s,
                            None => {
                                let s = probe_keys.len();
                                probe_index.insert(pkey.clone(), s);
                                probe_keys.push(pkey);
                                probe_consumers.push(Vec::new());
                                s
                            }
                        };
                        probe_consumers[slot].push((pi, level, err));
                    }
                    pend_cells.push(cell);
                    pend_keys.push(key);
                }
            }
        }
        // ONE extra batched epoch probes every acceptable ladder level
        // of every fine-level miss (DESIGN.md §10)
        let mut best: Vec<Option<(u32, f64, Vec<u8>)>> =
            vec![None; pend_cells.len()];
        if !probe_keys.is_empty() {
            let got = d.read_batch(&probe_keys);
            for (consumers, val) in
                probe_consumers.into_iter().zip(got.into_iter())
            {
                if let Some(v) = val {
                    for (pi, level, err) in consumers {
                        let finer = matches!(&best[pi], Some((bl, _, _)) if *bl <= level);
                        if !finer {
                            best[pi] = Some((level, err, v.clone()));
                        }
                    }
                }
            }
        }
        for ((cell, key), hit) in pend_cells
            .into_iter()
            .zip(pend_keys.into_iter())
            .zip(best.into_iter())
        {
            match hit {
                Some((level, err, v)) => {
                    out.hits += 1;
                    d.note_ladder_hit(level as usize, err);
                    out.updates.push((cell, unpack_value(&v)));
                    // back-fill: next round's fine lookup hits directly
                    store_keys.push(key);
                    store_vals.push(v);
                }
                None => {
                    out.misses += 1;
                    miss_cells.push(cell);
                    miss_rows.extend_from_slice(&rows[cell - lo]);
                    miss_keys.push(Some(key));
                }
            }
        }
    } else {
        for cell in lo..hi {
            miss_cells.push(cell);
            miss_rows.extend_from_slice(&grid.row(cell, dt));
        }
    }

    if !miss_cells.is_empty() {
        let n = miss_cells.len();
        // engine stress knob: repeat the batch
        for _ in 1..chem_repeat.max(1) {
            let _ = chem.run(&miss_rows, n).expect("chemistry engine");
        }
        let res = chem.run(&miss_rows, n).expect("chemistry engine");
        // full-physics cost emulation: spin per simulated cell
        if cfg.chem_extra_us > 0.0 {
            let until = std::time::Instant::now()
                + std::time::Duration::from_micros(
                    (cfg.chem_extra_us * n as f64) as u64,
                );
            while std::time::Instant::now() < until {
                std::hint::spin_loop();
            }
        }
        out.chem_cells += n as u64;
        // neighbors coarsening to the same cell would store the same
        // coarse key once per producer; one write per distinct key in
        // this pass suffices (last-wins makes the rest pure overhead)
        let mut stored_coarse: std::collections::HashSet<Vec<u8>> =
            std::collections::HashSet::new();
        for (i, cell) in miss_cells.iter().enumerate() {
            let rec: [f64; N_OUT] =
                res[i * N_OUT..(i + 1) * N_OUT].try_into().unwrap();
            if dht.is_some() {
                if let Some(key) = miss_keys[i].take() {
                    let val = pack_row(&rec);
                    // store the acceptable coarser ladder levels too:
                    // future near-misses can only hit a coarse cell
                    // someone populated, and a producer outside the
                    // tolerance of its own coarse representative must
                    // not populate that cell (DESIGN.md §10).  probes()
                    // is recomputed rather than carried from the lookup
                    // phase: the clone/plumbing cost outweighs a few
                    // round_sig calls on a path dominated by chemistry
                    let row: [f64; N_IN] = miss_rows
                        [i * N_IN..(i + 1) * N_IN]
                        .try_into()
                        .unwrap();
                    for (_, ck, _) in lcfg.probes(&row) {
                        let ck = tkey(ck);
                        if stored_coarse.insert(ck.clone()) {
                            store_keys.push(ck);
                            store_vals.push(val.clone());
                        }
                    }
                    store_keys.push(key);
                    store_vals.push(val);
                }
            }
            out.updates.push((*cell, rec));
        }
    }
    if let Some(d) = dht.as_deref_mut() {
        if !store_keys.is_empty() {
            // ONE pipelined write pass: post-chemistry stores + ladder
            // back-fill
            d.write_batch(&store_keys, &store_vals);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poet::chemistry::NativeChemistry;

    fn small_driver(steps: usize, workers: usize) -> PoetDriver {
        let mut cfg = PoetConfig::small();
        cfg.steps = steps;
        cfg.workers = workers;
        cfg.ny = 12;
        cfg.nx = 36;
        cfg.inj_rows = 3;
        PoetDriver::with_default_waters(cfg, Arc::new(NativeChemistry))
    }

    #[test]
    fn reference_run_produces_front() {
        let mut d = small_driver(40, 1);
        let stats = d.run_reference();
        assert_eq!(stats.chem_cells, 40 * 12 * 36);
        assert!(stats.max_dolomite > 0.0, "dolomite front appeared");
        assert!(stats.inlet_calcite < 2.0e-4, "inlet calcite dissolving");
    }

    #[test]
    fn dht_run_matches_reference_closely_and_hits() {
        let mut ref_d = small_driver(30, 1);
        let ref_stats = ref_d.run_reference();
        for variant in Variant::ALL {
            let mut d = small_driver(30, 1);
            let stats = d.run_with_dht(variant);
            // cache must actually be used
            assert!(stats.hit_rate() > 0.5, "{variant:?}: {}", stats.hit_rate());
            assert!(stats.chem_cells < ref_stats.chem_cells / 2);
            // physics must agree with the reference within rounding error
            let d_dol =
                (stats.max_dolomite - ref_stats.max_dolomite).abs();
            assert!(
                d_dol <= 0.35 * ref_stats.max_dolomite.max(1e-12),
                "{variant:?}: dolomite {} vs {}",
                stats.max_dolomite,
                ref_stats.max_dolomite
            );
            assert_eq!(stats.dht.mismatches, 0);
        }
    }

    #[test]
    fn multi_worker_equivalent_to_single() {
        // 1 worker vs 3 workers, reference mode: identical physics
        let mut a = small_driver(15, 1);
        let sa = a.run_reference();
        let mut b = small_driver(15, 3);
        let sb = b.run_reference();
        assert_eq!(sa.chem_cells, sb.chem_cells);
        for (x, y) in a.grid.solutes.iter().zip(b.grid.solutes.iter()) {
            assert!((x - y).abs() < 1e-15);
        }
        for (x, y) in a.grid.minerals.iter().zip(b.grid.minerals.iter()) {
            assert!((x - y).abs() < 1e-15);
        }
    }

    #[test]
    fn mid_run_resize_recovers_hit_rate() {
        // an undersized table is eviction-bound; growing it mid-run must
        // lift the hit rate above the pre-resize steady state AND leave
        // the physics identical to the reference (the acceptance demo of
        // the elastic subsystem, DESIGN.md §8)
        let mut cfg = PoetConfig::small();
        cfg.steps = 60;
        cfg.workers = 2;
        cfg.ny = 12;
        cfg.nx = 36;
        cfg.inj_rows = 3;
        // lock-free bucket = 200 B -> ~40 buckets/rank for 432 cells:
        // the working set cannot fit before the resize
        cfg.win_bytes = 8 * 1024;
        cfg.resize_at_step = Some(30);
        cfg.resize_factor = 64.0;
        let mut d =
            PoetDriver::with_default_waters(cfg, Arc::new(NativeChemistry));
        let stats = d.run_with_dht(Variant::LockFree);
        assert_eq!(stats.dht.resizes, 1, "exactly one resize initiated");
        assert!(stats.dht.migrated > 0, "cooperative migration ran");
        assert_eq!(stats.dht.mismatches, 0, "no wrong values mid-resize");
        let pre = stats.hit_rate_over(20, 30);
        let post = stats.hit_rate_over(50, 60);
        assert!(
            post > pre,
            "hit rate must recover after the resize: pre {pre:.3} vs \
             post {post:.3}"
        );
        // physics still matches the reference run
        let mut r = small_driver(60, 1);
        let ref_stats = r.run_reference();
        let d_dol = (stats.max_dolomite - ref_stats.max_dolomite).abs();
        assert!(
            d_dol <= 0.35 * ref_stats.max_dolomite.max(1e-12),
            "dolomite {} vs reference {}",
            stats.max_dolomite,
            ref_stats.max_dolomite
        );
    }

    #[test]
    fn replicated_run_matches_reference_physics() {
        let mut ref_d = small_driver(20, 1);
        let ref_stats = ref_d.run_reference();
        let mut d = small_driver(20, 2);
        d.cfg.replicas = 2;
        let stats = d.run_with_dht(Variant::LockFree);
        assert!(stats.hit_rate() > 0.5, "hit rate {}", stats.hit_rate());
        assert!(stats.dht.replica_writes > 0, "copies fanned out");
        assert_eq!(
            stats.dht.replica_writes, stats.dht.writes,
            "one copy per primary write at k=2"
        );
        let d_dol = (stats.max_dolomite - ref_stats.max_dolomite).abs();
        assert!(
            d_dol <= 0.35 * ref_stats.max_dolomite.max(1e-12),
            "dolomite {} vs {}",
            stats.max_dolomite,
            ref_stats.max_dolomite
        );
    }

    #[test]
    fn threaded_kill_with_repair_rehomes_copies() {
        // kill one of four workers mid-run under real thread
        // concurrency: the surviving workers' piggybacked repair quanta
        // re-home the lost copies, the cache keeps serving through
        // failover, and the physics stays correct (DESIGN.md §11)
        let mut cfg = PoetConfig::small();
        cfg.steps = 40;
        cfg.workers = 4;
        cfg.ny = 12;
        cfg.nx = 36;
        cfg.inj_rows = 3;
        cfg.replicas = 2;
        cfg.repair = true;
        // 128 KiB -> ~650 lock-free buckets/rank: the default repair
        // quantum finishes a full shard pass well before the run ends
        cfg.win_bytes = 128 * 1024;
        cfg.kill_at_step = Some((10, 2));
        let mut d =
            PoetDriver::with_default_waters(cfg, Arc::new(NativeChemistry));
        let stats = d.run_with_dht(Variant::LockFree);
        assert!(stats.dht.repaired > 0, "live workers re-homed copies");
        assert_eq!(stats.dht.ranks_dead, 1, "the kill is held at exit");
        assert_eq!(stats.dht.mismatches, 0, "no wrong values mid-repair");
        assert!(
            stats.hit_rate_over(30, 40) > 0.5,
            "final-window hit rate {}",
            stats.hit_rate_over(30, 40)
        );
        let mut r = small_driver(40, 1);
        let ref_stats = r.run_reference();
        let d_dol = (stats.max_dolomite - ref_stats.max_dolomite).abs();
        assert!(
            d_dol <= 0.35 * ref_stats.max_dolomite.max(1e-12),
            "dolomite {} vs reference {}",
            stats.max_dolomite,
            ref_stats.max_dolomite
        );
    }

    #[test]
    fn non_finite_states_bypass_the_dht() {
        // regression: NaN species used to round to 0.0 and alias the
        // all-zero state's key, so a corrupted state could return a
        // bogus surrogate hit; now such rows skip the DHT entirely
        let mut cfg = PoetConfig::small();
        cfg.steps = 3;
        cfg.workers = 2;
        cfg.ny = 8;
        cfg.nx = 12;
        cfg.inj_rows = 2;
        let (bg, inj, min0) = crate::poet::chemistry::default_waters();
        let mut bad_bg = bg.clone();
        bad_bg[0] = f64::NAN;
        let mut d = PoetDriver::new(
            cfg,
            Arc::new(NativeChemistry),
            &bad_bg,
            &inj,
            &min0,
        );
        let stats = d.run_with_dht(Variant::LockFree);
        assert!(
            stats.dht.nonfinite_skips > 0,
            "NaN rows must bypass the DHT"
        );
        // bypassed rows still went through chemistry (counted as misses)
        assert!(stats.chem_cells >= stats.dht.nonfinite_skips);
        assert_eq!(stats.dht.mismatches, 0);
        // a fully-finite run never trips the counter
        let mut ok = small_driver(5, 1);
        let s = ok.run_with_dht(Variant::LockFree);
        assert_eq!(s.dht.nonfinite_skips, 0);
    }

    #[test]
    fn tenant_sharded_workers_namespace_the_cache() {
        // 4 workers block-partitioned across 2 tenant namespaces over
        // one shared cache with second-chance aging (DESIGN.md §14):
        // each tenant hits only its own writes, the per-tenant ledger
        // reconciles with the global counters, and the physics is
        // untouched by the namespacing
        let mut d = small_driver(20, 4);
        d.cfg.tenants = 2;
        d.cfg.evict = EvictPolicy::SecondChance;
        let stats = d.run_with_dht(Variant::LockFree);
        assert_eq!(stats.tenant_hits.len(), 2);
        for t in 0..2 {
            let (h, m) = stats.tenant_hits[t];
            assert!(h + m > 0, "tenant {t} issued lookups");
            assert!(h > 0, "tenant {t} hits its own writes");
        }
        let (h0, m0) = stats.tenant_hits[0];
        let (h1, m1) = stats.tenant_hits[1];
        assert_eq!(h0 + h1, stats.cache_hits, "hit ledger conserved");
        assert_eq!(
            h0 + m0 + h1 + m1,
            stats.cache_hits + stats.cache_misses,
            "lookup ledger conserved"
        );
        let f = stats.fairness();
        assert!(f > 0.0 && f <= 1.0, "jain fairness {f}");
        assert_eq!(stats.dht.mismatches, 0);
        // namespaced surrogate, same physics
        let mut r = small_driver(20, 1);
        let ref_stats = r.run_reference();
        let d_dol = (stats.max_dolomite - ref_stats.max_dolomite).abs();
        assert!(
            d_dol <= 0.35 * ref_stats.max_dolomite.max(1e-12),
            "dolomite {} vs reference {}",
            stats.max_dolomite,
            ref_stats.max_dolomite
        );
    }

    #[test]
    fn single_tenant_ledger_mirrors_global_counters() {
        // tenants == 1 (the default) degenerates to one anonymous row —
        // the threaded half of the oracle anchor
        let mut d = small_driver(10, 2);
        let stats = d.run_with_dht(Variant::Coarse);
        assert_eq!(
            stats.tenant_hits,
            vec![(stats.cache_hits, stats.cache_misses)]
        );
        assert!((stats.fairness() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn hit_rate_grows_with_fewer_digits() {
        let mut coarse = small_driver(20, 1);
        coarse.cfg.digits = 3;
        let sc = coarse.run_with_dht(Variant::LockFree);
        let mut fine = small_driver(20, 1);
        fine.cfg.digits = 8;
        let sf = fine.run_with_dht(Variant::LockFree);
        assert!(
            sc.hit_rate() >= sf.hit_rate(),
            "3 digits {} vs 8 digits {}",
            sc.hit_rate(),
            sf.hit_rate()
        );
    }
}
