//! Geochemistry engines: the PJRT-backed engine (the real L1/L2 path) and
//! a bit-compatible native Rust reimplementation.
//!
//! [`NativeChemistry`] mirrors `python/compile/kernels/chemistry.py`
//! *constant for constant and clamp for clamp*; the integration tests
//! replay the AOT golden vectors through it and require agreement to
//! ~1e-12 relative.  It exists so that (a) the DES POET model can compute
//! real reaction results without paying a PJRT round trip per event, and
//! (b) POET tests run even without built artifacts.
//!
//! [`ChemCost`] converts a cell's reaction activity into *simulated*
//! PHREEQC time for the DES mode: equilibrated cells are cheap, cells on
//! the reaction front (large saturation disequilibrium) are expensive —
//! this is what makes the reference runs stop scaling in Fig. 7 and the
//! DHT pay off.

use crate::runtime::Engine;

/// Record widths (match the paper's 80 B key / 104 B value).
pub const N_IN: usize = 10;
pub const N_OUT: usize = 13;
pub const N_SPECIES: usize = 9;
pub const N_SOLUTES: usize = 7;

// --- constants mirrored from python/compile/kernels/chemistry.py ---------
const K1: f64 = 4.466835921509632e-7; // 10^-6.35
const K2: f64 = 4.677351412871983e-11; // 10^-10.33
const KSP_CAL: f64 = 3.311311214825911e-9; // 10^-8.48
const KSP_DOL: f64 = 8.128305161640995e-18; // 10^-17.09
const K_CAL: f64 = 1.5e-6;
const K_DOL: f64 = 3.0e-7;
const M_HALF: f64 = 1.0e-5;
const PH_BETA: f64 = 150.0;
const OMEGA_CAP: f64 = 1.0e3;
const EXT_CAP: f64 = 0.25;
const EXT_CAP_FLOOR: f64 = 1.0e-4;
const N_SUB: usize = 8;
const STATE_MIN: f64 = 1.0e-12;

/// TST rates + saturation ratios (mirrors `_rates` in the kernel).
#[inline]
fn rates(ca: f64, mg: f64, c: f64, ph: f64, calcite: f64, dolomite: f64)
         -> (f64, f64, f64, f64) {
    let h = 10f64.powf(-ph);
    let denom = h * h + K1 * h + K1 * K2;
    let a_co3 = c * (K1 * K2) / denom;
    let omega_cal = (ca * a_co3 / KSP_CAL).min(OMEGA_CAP);
    let omega_dol = (ca * mg * a_co3 * a_co3 / KSP_DOL).min(OMEGA_CAP);
    let f_cal = calcite / (calcite + M_HALF);
    let f_dol = dolomite / (dolomite + M_HALF);
    let mut r_cal = K_CAL * (1.0 - omega_cal);
    let mut r_dol = K_DOL * (1.0 - omega_dol);
    if r_cal > 0.0 {
        r_cal *= f_cal;
    }
    if r_dol > 0.0 {
        r_dol *= f_dol;
    }
    (r_cal, r_dol, omega_cal, omega_dol)
}

/// Integrate one cell over `dt` (mirrors `_integrate`): `row` = 10 inputs,
/// returns the 13-double output record.
pub fn integrate_cell(row: &[f64]) -> [f64; N_OUT] {
    let (mut ca, mut mg, mut c) = (row[0], row[1], row[2]);
    let (cl, mut ph, pe, o0) = (row[3], row[4], row[5], row[6]);
    let (mut calcite, mut dolomite) = (row[7], row[8]);
    let dts = row[9] / N_SUB as f64;

    for _ in 0..N_SUB {
        let (r_cal, r_dol, _, _) = rates(ca, mg, c, ph, calcite, dolomite);
        let cap_dol = EXT_CAP * (ca.min(mg) + EXT_CAP_FLOOR);
        let cap_cal = EXT_CAP * (ca + EXT_CAP_FLOOR);
        let mut d_dol = (r_dol * dts).clamp(-cap_dol, cap_dol);
        d_dol = d_dol.min(dolomite);
        d_dol = d_dol.max(-(mg - STATE_MIN));
        d_dol = d_dol.max(-(ca - STATE_MIN));
        d_dol = d_dol.max(-0.5 * (c - STATE_MIN));
        let mut d_cal = (r_cal * dts).clamp(-cap_cal, cap_cal);
        d_cal = d_cal.min(calcite);
        d_cal = d_cal.max(-(ca - STATE_MIN) - d_dol);
        d_cal = d_cal.max(-(c - STATE_MIN) - 2.0 * d_dol);
        ca += d_cal + d_dol;
        mg += d_dol;
        c += d_cal + 2.0 * d_dol;
        ph = (ph + PH_BETA * (d_cal + 2.0 * d_dol)).clamp(4.0, 11.0);
        calcite = (calcite - d_cal).max(0.0);
        dolomite = (dolomite - d_dol).max(0.0);
    }
    let (r_cal, r_dol, omega_cal, omega_dol) =
        rates(ca, mg, c, ph, calcite, dolomite);
    [ca, mg, c, cl, ph, pe, o0, calcite, dolomite,
     r_cal, r_dol, omega_cal, omega_dol]
}

/// The default waters, mirroring `python/compile/model.py` (background Ca
/// computed at exact calcite equilibrium so unreached cells are
/// stationary — the property the surrogate cache exploits).
pub fn default_waters() -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let (bg_ph, bg_c) = (8.0f64, 1.0e-3f64);
    let h = 10f64.powf(-bg_ph);
    let denom = h * h + K1 * h + K1 * K2;
    let a_co3 = bg_c * (K1 * K2) / denom;
    let ca_eq = KSP_CAL / a_co3;
    let background = vec![ca_eq, 1.0e-6, bg_c, 1.0e-5, bg_ph, 4.0, 2.5e-4];
    let injection = vec![1.0e-6, 2.0e-3, bg_c, 4.0e-3, bg_ph, 4.0, 2.5e-4];
    let minerals0 = vec![2.0e-4, 0.0];
    (background, injection, minerals0)
}

/// A geochemistry engine: `rows` is `n` cells x 10 doubles, returns
/// `n` x 13 doubles.
pub trait Chemistry: Send + Sync {
    fn run(&self, rows: &[f64], n: usize) -> anyhow::Result<Vec<f64>>;
    fn name(&self) -> &'static str;
}

/// The native mirror of the Pallas kernel (validated against goldens).
#[derive(Default)]
pub struct NativeChemistry;

impl Chemistry for NativeChemistry {
    fn run(&self, rows: &[f64], n: usize) -> anyhow::Result<Vec<f64>> {
        assert_eq!(rows.len(), n * N_IN);
        let mut out = Vec::with_capacity(n * N_OUT);
        for r in 0..n {
            out.extend_from_slice(&integrate_cell(&rows[r * N_IN..(r + 1) * N_IN]));
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The real L1/L2 path: AOT-compiled Pallas/JAX chemistry via PJRT.
///
/// The xla crate's PJRT client is not `Send` (Rc internals), so the engine
/// lives on a dedicated server thread and workers talk to it over
/// channels.  On this box PJRT execution is single-threaded anyway, so the
/// serialization costs nothing; on a larger machine one server per NUMA
/// domain would be the natural extension.
pub struct PjrtChemistry {
    tx: std::sync::Mutex<
        std::sync::mpsc::Sender<(
            Vec<f64>,
            usize,
            std::sync::mpsc::Sender<anyhow::Result<Vec<f64>>>,
        )>,
    >,
}

impl PjrtChemistry {
    /// Spawn the engine thread on `dir`'s artifacts; returns the handle
    /// and the parsed manifest (waters/constants for the driver).
    pub fn spawn(
        dir: std::path::PathBuf,
    ) -> anyhow::Result<(Self, crate::runtime::Manifest)> {
        let (tx, rx) = std::sync::mpsc::channel::<(
            Vec<f64>,
            usize,
            std::sync::mpsc::Sender<anyhow::Result<Vec<f64>>>,
        )>();
        let (ready_tx, ready_rx) =
            std::sync::mpsc::channel::<anyhow::Result<crate::runtime::Manifest>>();
        std::thread::Builder::new()
            .name("pjrt-chemistry".into())
            .spawn(move || {
                let engine = match Engine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(e.manifest().clone()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok((rows, n, reply)) = rx.recv() {
                    let _ = reply.send(engine.chemistry(&rows, n));
                }
            })
            .expect("spawn pjrt thread");
        let manifest = ready_rx.recv().expect("pjrt thread handshake")?;
        Ok((Self { tx: std::sync::Mutex::new(tx) }, manifest))
    }
}

impl Chemistry for PjrtChemistry {
    fn run(&self, rows: &[f64], n: usize) -> anyhow::Result<Vec<f64>> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send((rows.to_vec(), n, reply_tx))
            .map_err(|_| anyhow::anyhow!("pjrt thread gone"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("pjrt thread gone"))?
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Simulated PHREEQC cost of one cell (DES mode, Fig. 7).
///
/// PHREEQC converges quickly on equilibrated cells and grinds on cells far
/// from equilibrium; we model per-cell cost as base + activity-scaled
/// component, where activity is the relative saturation disequilibrium.
#[derive(Clone, Copy, Debug)]
pub struct ChemCost {
    /// Cost of an equilibrated cell, ns.
    pub base_ns: u64,
    /// Extra cost of a fully active (front) cell, ns.
    pub active_ns: u64,
}

impl Default for ChemCost {
    fn default() -> Self {
        // calibrated against Fig. 7's reference run (603 s at 128 ranks on
        // the paper's 500x1500 grid => ~206 µs/cell average with the front
        // covering a few percent of the domain)
        Self { base_ns: 120_000, active_ns: 4_000_000 }
    }
}

impl ChemCost {
    /// Mineral turnover relative to this scale counts as "fully active".
    pub const ACTIVITY_SCALE: f64 = 2.0e-5;

    /// Activity in [0,1]: how much mineral mass actually reacted this
    /// step (equilibrated cells react ~0; front cells convert a sizeable
    /// fraction of their calcite/dolomite).
    pub fn activity(in_row: &[f64], out_row: &[f64]) -> f64 {
        let d_cal = (out_row[7] - in_row[7]).abs();
        let d_dol = (out_row[8] - in_row[8]).abs();
        ((d_cal + d_dol) / Self::ACTIVITY_SCALE).min(1.0)
    }

    pub fn cost_ns(&self, in_row: &[f64], out_row: &[f64]) -> u64 {
        self.base_ns
            + (self.active_ns as f64 * Self::activity(in_row, out_row)) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_row() -> [f64; N_IN] {
        [5e-4, 1e-3, 1e-3, 2e-3, 8.5, 4.0, 2.5e-4, 2e-4, 0.0, 500.0]
    }

    #[test]
    fn native_matches_python_constants() {
        // 10^-6.35 etc. — guard against typos in the mirrored constants
        assert!((K1 - 10f64.powf(-6.35)).abs() / K1 < 1e-12);
        assert!((K2 - 10f64.powf(-10.33)).abs() / K2 < 1e-12);
        assert!((KSP_CAL - 10f64.powf(-8.48)).abs() / KSP_CAL < 1e-12);
        assert!((KSP_DOL - 10f64.powf(-17.09)).abs() / KSP_DOL < 1e-12);
    }

    #[test]
    fn mg_rich_water_precipitates_dolomite() {
        let out = integrate_cell(&sample_row());
        assert!(out[8] > 0.0, "dolomite formed: {}", out[8]);
        assert!(out[7] <= 2e-4 + 1e-18); // calcite consumed or equal
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn conservative_species_untouched() {
        let row = sample_row();
        let out = integrate_cell(&row);
        assert_eq!(out[3], row[3]);
        assert_eq!(out[5], row[5]);
        assert_eq!(out[6], row[6]);
    }

    #[test]
    fn dt_zero_identity() {
        let mut row = sample_row();
        row[9] = 0.0;
        let out = integrate_cell(&row);
        for i in 0..N_SPECIES {
            assert!((out[i] - row[i]).abs() < 1e-15);
        }
    }

    #[test]
    fn background_water_is_stationary() {
        let (bg, _, min0) = default_waters();
        let mut row = [0.0; N_IN];
        row[..7].copy_from_slice(&bg);
        row[7] = min0[0];
        row[8] = min0[1];
        row[9] = 2000.0;
        let out = integrate_cell(&row);
        for i in 0..N_SPECIES {
            let tol = 1e-9 * row[i].abs().max(1e-12);
            assert!((out[i] - row[i]).abs() < tol.max(1e-12),
                    "species {i}: {} -> {}", row[i], out[i]);
        }
        // at equilibrium: omega_cal == 1
        assert!((out[11] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn batch_engine_matches_per_cell() {
        let row = sample_row();
        let mut rows = Vec::new();
        for i in 0..5 {
            let mut r = row;
            r[1] += i as f64 * 1e-5;
            rows.extend_from_slice(&r);
        }
        let out = NativeChemistry.run(&rows, 5).unwrap();
        for i in 0..5 {
            let mut r = row;
            r[1] += i as f64 * 1e-5;
            let single = integrate_cell(&r);
            assert_eq!(&out[i * N_OUT..(i + 1) * N_OUT], &single[..]);
        }
    }

    #[test]
    fn cost_model_orders_front_vs_equilibrium() {
        let cost = ChemCost::default();
        let (bg, _, min0) = default_waters();
        let mut eq_row = [0.0; N_IN];
        eq_row[..7].copy_from_slice(&bg);
        eq_row[7] = min0[0];
        eq_row[9] = 2000.0;
        let eq_out = integrate_cell(&eq_row);
        let front_row = sample_row();
        let front_out = integrate_cell(&front_row);
        assert!(cost.cost_ns(&eq_row, &eq_out) < cost.cost_ns(&front_row, &front_out));
        assert!(cost.cost_ns(&eq_row, &eq_out) >= cost.base_ns);
        // equilibrated cell is near base cost; front cell near full cost
        assert!(cost.cost_ns(&eq_row, &eq_out) < cost.base_ns + cost.active_ns / 10);
        assert!(cost.cost_ns(&front_row, &front_out) > cost.base_ns + cost.active_ns / 2);
    }
}
