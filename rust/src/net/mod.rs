//! Calibrated network model for the DES backend.
//!
//! Models the two testbeds of the paper:
//!
//! * `turing_roce` — University of Potsdam Turing cluster: 2×12-core Xeon
//!   nodes, RoCE ConnectX-6 Dx 100 Gbit (Fig. 3, DAOS comparison).
//! * `pik_ndr`     — PIK cluster: 2×64-core EPYC 9554 nodes, ConnectX-7
//!   NDR 400 Gbit InfiniBand (Figs. 4–7, Tables 1–4).
//!
//! Cost model per one-sided operation (see DESIGN.md §2): an origin-side
//! software cost, an origin-NIC serialization, a wire latency, and a
//! target-side responder occupancy (fixed cost + byte-proportional DMA
//! term).  Atomics additionally serialize on the target HCA's atomic unit
//! — which is exactly what makes lock busy-wait loops collapse under
//! contention, the paper's central observation (§3.5).  Same-node
//! operations bypass the NIC (shared-memory path).
//!
//! The dials are calibrated so that *single-op latencies* and *plateau
//! throughputs* land in the paper's reported bands; the protocol behaviour
//! (who wins, where locking collapses) is emergent, not fitted.

use crate::sim::{Resource, Time};
use crate::util::rng::SplitMix64;

pub mod topology;

pub use topology::{Fabric, LinkModel, Topology};

/// Calibration profile + topology for a simulated cluster.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// MPI ranks (processes) per node ("dense mapping" in the paper).
    pub ranks_per_node: u32,
    /// Origin-side software cost per one-sided op (MPI/UCX stack), ns.
    pub sw_ns: u64,
    /// One-way wire + switch latency between nodes, ns.
    pub wire_ns: u64,
    /// Fixed origin-NIC serialization per message, ns.
    pub nic_fix_ns: u64,
    /// NIC wire bandwidth, bytes per ns (100 Gbit ≈ 12.5, 400 Gbit ≈ 50).
    pub bw_bytes_per_ns: f64,
    /// Fixed target-side responder cost per message (PCIe/DMA setup), ns.
    pub resp_fix_ns: u64,
    /// Target-side DMA effective bandwidth for payload movement, bytes/ns.
    pub dma_bytes_per_ns: f64,
    /// Occupancy of the target HCA atomic unit per remote atomic, ns.
    pub atomic_ns: u64,
    /// Same-node (shared-memory) op latency, ns.
    pub intra_ns: u64,
    /// Same-node atomic latency, ns.
    pub intra_atomic_ns: u64,
    /// Atomics per `MPI_Win_lock` acquisition attempt.  Open MPI's
    /// passive-target busy loop issues "compare-and-swap, atomic fetch,
    /// and atomic fetch-and-add" per attempt (paper §3.5) — this is what
    /// makes the coarse-grained DHT collapse.
    pub win_lock_atomics: u32,
    /// Atomics per `MPI_Win_unlock`.
    pub win_unlock_atomics: u32,
    /// Atomics per shared (reader) `MPI_Win_lock` attempt.
    pub win_shared_atomics: u32,
    /// Max per-op software-cost jitter, ns (deterministic PRNG).  Without
    /// jitter the DES phase-locks: constant service times make rank op
    /// cycles commensurate, so concurrent accesses either always or never
    /// overlap a DMA window.  ~half an op's software cost of jitter
    /// restores the continuous-time overlap statistics.
    pub jitter_ns: u64,
    /// Parallel DMA/responder lanes per node.  Aggregate capacity stays
    /// `1/(resp_fix + bytes/dma)` (per-op occupancy is multiplied by the
    /// lane count), but concurrent transfers on different lanes can
    /// overlap in time — which is what makes torn reads (and hence the
    /// paper's checksum mismatches, Tab. 2/4) physically possible.
    pub resp_lanes: u32,
    /// Whether same-node ops occupy the node's NIC/responder/atomic
    /// resources.  True for UCX loopback (PIK, Open MPI 5 — makes Fig. 4
    /// scale linearly in nodes); false for a cheap shared-memory BTL
    /// (Turing, Open MPI 4.1).
    pub intra_uses_node_resources: bool,
    /// Owner-CPU occupancy per delegated mailbox op (DESIGN.md §12): the
    /// serialized probe-walk + memcpy the owning rank performs when it
    /// drains one mailbox entry.  This is the delegated variant's
    /// skew-dependent bottleneck — every op on a rank's shard queues on
    /// its single owner, so a hot key turns this number into the service
    /// time of an M/D/1-like queue.
    pub mailbox_serve_ns: u64,
    /// Fabric shape connecting the nodes (DESIGN.md §13).  `Crossbar`
    /// reproduces the historical flat model bit-identically.
    pub topology: Topology,
    /// How messages consume link capacity along a route.  Irrelevant for
    /// the crossbar (it has no shared links).
    pub link_model: LinkModel,
    /// Per-link bandwidth in bytes/ns (0 = same as `bw_bytes_per_ns`,
    /// i.e. the fabric matches NIC line rate).
    pub link_bw_bytes_per_ns: f64,
    /// Per-hop switch + propagation latency, ns (0 = `wire_ns / 4`, so a
    /// 4-link inter-pod fat-tree route costs exactly one flat `wire_ns`).
    pub hop_ns: u64,
    /// Deterministic background traffic: the fraction of every fabric
    /// link's capacity consumed by other jobs' flows.  Foreground
    /// serialization stretches by `1/(1-load)`; 0 = dedicated fabric.
    /// Has no effect on the crossbar (dedicated per-pair capacity).
    pub bg_load: f64,
}

impl NetConfig {
    /// Turing cluster (RoCE 100G, Open MPI 4.1): Fig. 3 testbed.
    pub fn turing_roce() -> Self {
        Self {
            ranks_per_node: 24,
            sw_ns: 900,
            wire_ns: 1_450,
            nic_fix_ns: 70,
            bw_bytes_per_ns: 12.5,
            resp_fix_ns: 260,
            dma_bytes_per_ns: 0.8,
            atomic_ns: 340,
            intra_ns: 250,
            intra_atomic_ns: 60,
            win_lock_atomics: 3,
            win_unlock_atomics: 2,
            win_shared_atomics: 2,
            jitter_ns: 400,
            resp_lanes: 2,
            intra_uses_node_resources: false,
            mailbox_serve_ns: 220,
            topology: Topology::Crossbar,
            link_model: LinkModel::Constant,
            link_bw_bytes_per_ns: 0.0,
            hop_ns: 0,
            bg_load: 0.0,
        }
    }

    /// PIK cluster (NDR 400G IB, Open MPI 5.0.6 + UCX): Figs. 4–7 testbed.
    pub fn pik_ndr() -> Self {
        Self {
            ranks_per_node: 128,
            sw_ns: 350,
            wire_ns: 900,
            nic_fix_ns: 18,
            bw_bytes_per_ns: 50.0,
            resp_fix_ns: 120,
            dma_bytes_per_ns: 2.4,
            atomic_ns: 300,
            intra_ns: 180,
            intra_atomic_ns: 45,
            win_lock_atomics: 3,
            win_unlock_atomics: 2,
            win_shared_atomics: 2,
            jitter_ns: 240,
            resp_lanes: 2,
            intra_uses_node_resources: true,
            mailbox_serve_ns: 150,
            topology: Topology::Crossbar,
            link_model: LinkModel::Constant,
            link_bw_bytes_per_ns: 0.0,
            hop_ns: 0,
            bg_load: 0.0,
        }
    }

    #[inline]
    pub fn node_of(&self, rank: u32) -> u32 {
        rank / self.ranks_per_node
    }

    pub fn nodes_for(&self, nranks: u32) -> u32 {
        nranks.div_ceil(self.ranks_per_node)
    }
}

/// Kinds of one-sided operations the model distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// RDMA read: small request out, `bytes` response back.
    Get,
    /// RDMA write: `bytes` request out, small ack back.
    Put,
    /// Remote atomic (CAS / fetch-and-op): 8-byte operands both ways.
    Atomic,
    /// One-way eager message (RPC request / mailbox deposit): `bytes`
    /// out, no wire-level response — the application-level reply is a
    /// separate [`Network::reply`] message.  `resume == exec`.
    Send,
}

/// Completion timeline of one modelled op.
#[derive(Clone, Copy, Debug)]
pub struct OpTiming {
    /// Instant at which the op logically executes at the target (memory
    /// read/write/atomic application point — the serialization instant).
    pub exec: Time,
    /// Instant at which the origin rank resumes (response received).
    pub resume: Time,
    /// Duration the target memory region is being written (torn-read
    /// window for puts; 0 otherwise).
    pub write_dur: Time,
}

/// Per-node serialized resources.
#[derive(Debug)]
struct NodeRes {
    nic_tx: Resource,
    /// Parallel DMA lanes (see `NetConfig::resp_lanes`).
    responder: Vec<Resource>,
    atomic: Resource,
}

impl NodeRes {
    /// Least-loaded responder lane.
    fn lane(&mut self) -> &mut Resource {
        self.responder
            .iter_mut()
            .min_by_key(|r| r.next_free())
            .expect("at least one lane")
    }
}

/// The cluster network: per-node resources, the fabric links, and the
/// calibration profile.
#[derive(Debug)]
pub struct Network {
    pub cfg: NetConfig,
    nodes: Vec<NodeRes>,
    fabric: Fabric,
    jitter: SplitMix64,
    pub messages: u64,
    pub bytes: u128,
}

impl Network {
    pub fn new(cfg: NetConfig, nranks: u32) -> Self {
        let n = cfg.nodes_for(nranks).max(1);
        let lanes = cfg.resp_lanes.max(1) as usize;
        let nodes = (0..n)
            .map(|_| NodeRes {
                nic_tx: Resource::new(),
                responder: (0..lanes).map(|_| Resource::new()).collect(),
                atomic: Resource::new(),
            })
            .collect();
        let fabric = Fabric::new(cfg.topology, n);
        Self {
            cfg,
            nodes,
            fabric,
            jitter: SplitMix64::new(0x91E7),
            messages: 0,
            bytes: 0,
        }
    }

    /// Move one already-serialized message across the fabric.  `t` is
    /// the instant the origin NIC finished transmitting; the return is
    /// the arrival instant at the destination node.  `tail_ser` adds the
    /// receive-side serialization term the flat model charges responses
    /// (topology routes charge serialization on the links themselves).
    ///
    /// Associated fn (not a method) so callers can hold disjoint borrows
    /// of `cfg` / `nodes` while routing.
    fn transit(
        cfg: &NetConfig,
        fabric: &mut Fabric,
        t: Time,
        from_node: usize,
        to_node: usize,
        bytes: u32,
        tail_ser: bool,
    ) -> Time {
        if matches!(cfg.topology, Topology::Crossbar) {
            // flat model: constant wire latency, dedicated capacity
            let tail = if tail_ser {
                (bytes as f64 / cfg.bw_bytes_per_ns) as u64
            } else {
                0
            };
            return t + cfg.wire_ns + tail;
        }
        let hop = if cfg.hop_ns > 0 { cfg.hop_ns } else { cfg.wire_ns / 4 };
        let bw = if cfg.link_bw_bytes_per_ns > 0.0 {
            cfg.link_bw_bytes_per_ns
        } else {
            cfg.bw_bytes_per_ns
        };
        // background flows eat a fixed fraction of every link's
        // capacity: foreground serialization stretches by 1/(1-load)
        let load = cfg.bg_load.clamp(0.0, 0.95);
        let ser = ((bytes as f64 / bw) / (1.0 - load)) as u64;
        let route = fabric.route(from_node as u32, to_node as u32);
        let mut at = t;
        match cfg.link_model {
            LinkModel::Constant => {
                // uncontended cut-through: per-hop latency plus one
                // bottleneck serialization; flows never interact
                for &(_, hops) in route.iter() {
                    at += hops as u64 * hop;
                }
                at + ser
            }
            LinkModel::Shared => {
                // store-and-forward over shared links: each link keeps
                // a busy calendar, so concurrent flows queue and
                // congestion emerges where routes overlap
                for &(link, hops) in route.iter() {
                    at = fabric.links[link as usize].cal.acquire(at, ser);
                    at += hops as u64 * hop;
                }
                at
            }
        }
    }

    /// Model one one-sided op of `kind` moving `bytes` of payload from
    /// `from` to `to`, issued at `now`.  Returns the op timing.
    pub fn rma(&mut self, now: Time, from: u32, to: u32, kind: OpKind,
               bytes: u32) -> OpTiming {
        self.messages += 1;
        // request/response framing on the wire per op kind
        let (out_bytes, back_bytes) = match kind {
            OpKind::Get => (32u32, bytes),
            OpKind::Put => (bytes, 16u32),
            OpKind::Atomic => (16, 16),
            OpKind::Send => (bytes, 0),
        };
        // account actual on-wire bytes, not just payload: a get also
        // ships its 32-byte request, a put its 16-byte ack, an atomic
        // 16-byte operand messages both ways
        self.bytes += out_bytes as u128 + back_bytes as u128;
        let c = &self.cfg;
        let from_node = c.node_of(from) as usize;
        let to_node = c.node_of(to) as usize;
        let jitter = if c.jitter_ns > 0 {
            self.jitter.next_u64() % c.jitter_ns
        } else {
            0
        };
        let t0 = now + c.sw_ns + jitter;

        if from_node == to_node && !c.intra_uses_node_resources {
            // cheap shared-memory BTL: latency only, no shared resources
            let lat = match kind {
                OpKind::Atomic => c.intra_atomic_ns,
                _ => c.intra_ns
                    + (bytes as f64 / (4.0 * c.bw_bytes_per_ns)) as u64,
            };
            let exec = t0 + lat;
            let write_dur =
                if kind == OpKind::Put { (bytes as u64 / 16).max(1) } else { 0 };
            let resume =
                if kind == OpKind::Send { exec } else { exec + lat / 2 };
            return OpTiming { exec, resume, write_dur };
        }
        // Same-node one-sided ops under UCX still run the full loopback
        // path: lower wire latency, same per-node processing resources —
        // this is what makes Fig. 4 scale ~linearly in nodes.

        // origin NIC serializes the outgoing message
        let tx_occ = c.nic_fix_ns + (out_bytes as f64 / c.bw_bytes_per_ns) as u64;
        let t_tx = self.nodes[from_node].nic_tx.acquire(t0, tx_occ);
        // loopback, or the fabric route, to the target
        let t_arrive = if from_node == to_node {
            t_tx + self.cfg.intra_ns
        } else {
            Self::transit(
                &self.cfg,
                &mut self.fabric,
                t_tx,
                from_node,
                to_node,
                out_bytes,
                false,
            )
        };
        let c = &self.cfg;
        // target-side execution: responder (DMA) or atomic unit
        let (exec, write_dur) = match kind {
            OpKind::Atomic => {
                let occ = c.atomic_ns;
                (self.nodes[to_node].atomic.acquire(t_arrive, occ), 0)
            }
            OpKind::Get | OpKind::Send => {
                let occ = (c.resp_fix_ns
                    + (bytes as f64 / c.dma_bytes_per_ns) as u64)
                    * c.resp_lanes.max(1) as u64;
                (self.nodes[to_node].lane().acquire(t_arrive, occ), 0)
            }
            OpKind::Put => {
                let occ = (c.resp_fix_ns
                    + (bytes as f64 / c.dma_bytes_per_ns) as u64)
                    * c.resp_lanes.max(1) as u64;
                let done = self.nodes[to_node].lane().acquire(t_arrive, occ);
                // the memory region is torn while the DMA engine writes it
                let dur = ((bytes as f64 / c.dma_bytes_per_ns) as u64).max(1);
                (done, dur)
            }
        };
        // response back over the fabric (reads carry payload, which the
        // responder occupancy already accounted for); one-way sends have
        // no wire-level response
        let resume = if kind == OpKind::Send {
            exec
        } else if from_node == to_node {
            exec + c.intra_ns + (back_bytes as f64 / c.bw_bytes_per_ns) as u64
        } else {
            Self::transit(
                &self.cfg,
                &mut self.fabric,
                exec,
                to_node,
                from_node,
                back_bytes,
                true,
            )
        };
        OpTiming { exec, resume, write_dur }
    }

    /// Model a server→client response message (RPC reply / delegated
    /// mailbox completion): it serializes on the **server node's** NIC —
    /// owner response bandwidth is a real resource under fan-in — then
    /// rides the fabric, or the loopback path when both ranks share a
    /// node.  Returns the instant the client resumes.
    pub fn reply(&mut self, now: Time, from: u32, to: u32, bytes: u32) -> Time {
        self.messages += 1;
        self.bytes += bytes as u128;
        let c = &self.cfg;
        let from_node = c.node_of(from) as usize;
        let to_node = c.node_of(to) as usize;
        if from_node == to_node && !c.intra_uses_node_resources {
            // cheap shared-memory BTL, same as the request direction
            return now
                + c.intra_ns
                + (bytes as f64 / (4.0 * c.bw_bytes_per_ns)) as u64;
        }
        let tx_occ = c.nic_fix_ns + (bytes as f64 / c.bw_bytes_per_ns) as u64;
        let t_tx = self.nodes[from_node].nic_tx.acquire(now, tx_occ);
        if from_node == to_node {
            t_tx + self.cfg.intra_ns
        } else {
            Self::transit(
                &self.cfg,
                &mut self.fabric,
                t_tx,
                from_node,
                to_node,
                bytes,
                true,
            )
        }
    }

    /// Pure local compute on a rank; no shared resources.
    pub fn compute(&self, now: Time, ns: u64) -> Time {
        now + ns
    }

    pub fn responder_utilization(&self, node: usize, horizon: Time) -> f64 {
        let lanes = &self.nodes[node].responder;
        lanes.iter().map(|r| r.utilization(horizon)).sum::<f64>()
            / lanes.len() as f64
    }

    pub fn atomic_utilization(&self, node: usize, horizon: Time) -> f64 {
        self.nodes[node].atomic.utilization(horizon)
    }

    pub fn atomic_ops(&self, node: usize) -> u64 {
        self.nodes[node].atomic.ops
    }

    pub fn nnodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn nic_tx_utilization(&self, node: usize, horizon: Time) -> f64 {
        self.nodes[node].nic_tx.utilization(horizon)
    }

    /// Number of explicit fabric links (0 for the crossbar).
    pub fn nlinks(&self) -> usize {
        self.fabric.links.len()
    }

    /// Utilization of fabric link `i` over `[0, horizon]`.  Only the
    /// `Shared` link model accrues link occupancy; under `Constant` all
    /// links stay at zero (flows never interact).
    pub fn link_utilization(&self, i: usize, horizon: Time) -> f64 {
        self.fabric.links[i].cal.utilization(horizon)
    }

    /// Diagnostic label of fabric link `i` (e.g. `pod3.core1.up`).
    pub fn link_label(&self, i: usize) -> &str {
        &self.fabric.links[i].label
    }

    /// Hottest link over `[0, horizon]`: `(label, utilization)`.
    pub fn peak_link(&self, horizon: Time) -> Option<(&str, f64)> {
        self.fabric
            .links
            .iter()
            .map(|l| (l.label.as_str(), l.cal.utilization(horizon)))
            .max_by(|a, b| a.1.total_cmp(&b.1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(nranks: u32) -> Network {
        Network::new(NetConfig::pik_ndr(), nranks)
    }

    #[test]
    fn cross_node_get_latency_in_band() {
        let mut n = net(256);
        // rank 0 (node 0) reads a 200-byte bucket from rank 200 (node 1)
        let t = n.rma(0, 0, 200, OpKind::Get, 200);
        // paper band for DHT reads: single-digit µs uncontended
        assert!(t.resume > 2_000 && t.resume < 8_000, "resume={}", t.resume);
        assert!(t.exec < t.resume);
    }

    #[test]
    fn same_node_has_lower_latency_same_occupancy() {
        let mut n = net(256);
        let cross = n.rma(0, 0, 200, OpKind::Get, 200).resume;
        let mut n = net(256);
        let local = n.rma(0, 0, 100, OpKind::Get, 200).resume;
        // loopback saves the wire both ways but still pays the responder
        assert!(local < cross, "local={local} cross={cross}");
        assert!(local > cross / 4, "local={local} cross={cross}");
    }

    #[test]
    fn responder_serializes_under_contention() {
        let mut n = net(256);
        // many ranks on node 0 hammer rank 200 (node 1) simultaneously
        let mut last = 0;
        for r in 0..64 {
            let t = n.rma(0, r, 200, OpKind::Get, 200);
            last = last.max(t.resume);
        }
        // with ~280ns responder occupancy each, 64 ops ≈ 18µs of backlog
        assert!(last > 15_000, "last={last}");
    }

    #[test]
    fn origin_nic_shared_by_node_ranks() {
        let mut n = net(640);
        // ranks 0..128 are all on node 0: their TX serializes
        let t_first = n.rma(0, 0, 200, OpKind::Put, 200).resume;
        let mut t_last = 0;
        for r in 0..128 {
            t_last = n.rma(0, r, 300, OpKind::Put, 200).resume;
        }
        assert!(t_last > t_first);
    }

    #[test]
    fn atomic_uses_separate_unit() {
        let mut n = net(256);
        for _ in 0..100 {
            n.rma(0, 0, 200, OpKind::Atomic, 8);
        }
        assert_eq!(n.atomic_ops(1), 100);
        // responders untouched by atomics
        assert!(n.responder_utilization(1, 1_000_000) == 0.0);
    }

    #[test]
    fn put_has_torn_window() {
        let mut n = net(256);
        let t = n.rma(0, 0, 200, OpKind::Put, 200);
        assert!(t.write_dur >= 1);
        let g = n.rma(0, 0, 200, OpKind::Get, 200);
        assert_eq!(g.write_dur, 0);
    }

    #[test]
    fn node_mapping() {
        let c = NetConfig::pik_ndr();
        assert_eq!(c.node_of(0), 0);
        assert_eq!(c.node_of(127), 0);
        assert_eq!(c.node_of(128), 1);
        assert_eq!(c.nodes_for(640), 5);
        assert_eq!(c.nodes_for(1), 1);
    }

    #[test]
    fn bytes_count_wire_framing_not_just_payload() {
        let mut n = net(256);
        // get: 32-byte request out + 200-byte payload back
        n.rma(0, 0, 200, OpKind::Get, 200);
        assert_eq!(n.messages, 1);
        assert_eq!(n.bytes, 232);
        // put: 200-byte payload out + 16-byte ack back
        n.rma(0, 0, 200, OpKind::Put, 200);
        assert_eq!(n.bytes, 232 + 216);
        // atomic: 16-byte operand messages both ways (payload arg is 8)
        n.rma(0, 0, 200, OpKind::Atomic, 8);
        assert_eq!(n.bytes, 232 + 216 + 32);
        // one-way send: exactly its bytes, no response framing
        n.rma(0, 0, 200, OpKind::Send, 100);
        assert_eq!(n.bytes, 232 + 216 + 32 + 100);
        // reply: one message of exactly its bytes
        n.reply(0, 200, 0, 120);
        assert_eq!(n.messages, 5);
        assert_eq!(n.bytes, 232 + 216 + 32 + 100 + 120);
    }

    #[test]
    fn send_is_one_way() {
        let mut n = net(256);
        let t = n.rma(0, 0, 200, OpKind::Send, 96);
        // no wire-level response: the origin "resumes" at target exec
        assert_eq!(t.resume, t.exec);
        assert_eq!(t.write_dur, 0);
    }

    #[test]
    fn reply_same_node_cheaper_than_old_flat_charge() {
        // the pre-fix reply model charged every RPC/mailbox reply
        // `wire_ns + bytes/bw` regardless of locality; pin that the
        // modelled reply now beats that for same-node pairs and still
        // costs at least as much cross-node (it adds the owner NIC).
        // This is the arithmetic that moves ablation [5]'s del/lf ratio
        // up on any workload containing same-node delegated ops.
        let bytes = 120u32;
        let c = NetConfig::pik_ndr();
        let old_charge =
            c.wire_ns + (bytes as f64 / c.bw_bytes_per_ns) as u64;
        let mut n = net(256);
        let same = n.reply(0, 1, 5, bytes); // ranks 1->5: both node 0
        let mut n = net(256);
        let cross = n.reply(0, 1, 200, bytes); // node 0 -> node 1
        assert!(same < old_charge, "same={same} old={old_charge}");
        assert!(cross >= old_charge, "cross={cross} old={old_charge}");
        assert!(same < cross, "same={same} cross={cross}");

        // cheap-BTL profile (Turing): same-node replies bypass the NIC
        let mut n = Network::new(NetConfig::turing_roce(), 48);
        let same = n.reply(0, 1, 5, bytes);
        assert_eq!(n.nic_tx_utilization(0, 1_000_000), 0.0);
        assert!(same < NetConfig::turing_roce().wire_ns);
    }

    #[test]
    fn reply_serializes_on_server_nic() {
        let mut n = net(256);
        // rank 200 (node 1) answers a fan-in of 64 clients on node 0:
        // the replies must queue on node 1's TX NIC
        let mut last = 0;
        for _ in 0..64 {
            last = last.max(n.reply(0, 200, 0, 4096));
        }
        let occ = 18 + (4096.0 / 50.0) as u64; // nic_fix + bytes/bw
        assert!(last >= 64 * occ, "last={last}");
        assert!(n.nic_tx_utilization(1, last) > 0.5);
        // and the clients' node NIC is untouched by replies
        assert_eq!(n.nic_tx_utilization(0, last), 0.0);
    }

    #[test]
    fn crossbar_ignores_link_model_and_bg() {
        // the flat model has dedicated per-pair capacity: link model and
        // background load must not change a single timing
        let mut a = net(640);
        let mut cfg = NetConfig::pik_ndr();
        cfg.link_model = LinkModel::Shared;
        cfg.bg_load = 0.9;
        let mut b = Network::new(cfg, 640);
        for r in 0..64 {
            let ta = a.rma(r as u64 * 11, r, 500, OpKind::Get, 200);
            let tb = b.rma(r as u64 * 11, r, 500, OpKind::Get, 200);
            assert_eq!(ta.exec, tb.exec);
            assert_eq!(ta.resume, tb.resume);
        }
        assert_eq!(a.nlinks(), 0);
    }

    #[test]
    fn fat_tree_core_link_congests_under_shared_model() {
        // 4 nodes in pods of 2; ranks on node 0 read big payloads from
        // BOTH pod-1 nodes while background jobs hold 90 % of the
        // fabric: the two response flows converge on pod0's single core
        // downlink (and n0's downlink) and must queue there.
        let mut cfg = NetConfig::pik_ndr();
        cfg.topology = Topology::FatTree { pod: 2, oversub: 2 };
        cfg.link_model = LinkModel::Shared;
        cfg.bg_load = 0.9;
        let mut n = Network::new(cfg.clone(), 512);
        let mut last = 0;
        for r in 0..32 {
            last = last.max(n.rma(0, r, 300, OpKind::Get, 60_000).resume);
            last = last.max(n.rma(0, r, 430, OpKind::Get, 60_000).resume);
        }
        let (label, util) = n.peak_link(last).unwrap();
        assert!(util > 0.3, "peak {label} util={util}");
        assert!(
            label.contains("core") || label.contains(".down"),
            "hot link should be core/down, got {label}"
        );
        // constant model: same traffic and bg, but flows never interact
        // — no link occupancy, and a strictly earlier finish
        cfg.link_model = LinkModel::Constant;
        let mut m = Network::new(cfg, 512);
        let mut last_c = 0;
        for r in 0..32 {
            last_c = last_c.max(m.rma(0, r, 300, OpKind::Get, 60_000).resume);
            last_c = last_c.max(m.rma(0, r, 430, OpKind::Get, 60_000).resume);
        }
        assert_eq!(m.peak_link(last_c).unwrap().1, 0.0);
        assert!(last > last_c, "shared {last} <= constant {last_c}");
    }

    #[test]
    fn bg_traffic_stretches_fabric_serialization() {
        let mut cfg = NetConfig::pik_ndr();
        cfg.topology = Topology::FatTree { pod: 2, oversub: 2 };
        cfg.link_model = LinkModel::Shared;
        let mut quiet = Network::new(cfg.clone(), 512);
        cfg.bg_load = 0.9;
        let mut busy = Network::new(cfg, 512);
        let q = quiet.rma(0, 0, 300, OpKind::Get, 8_192).resume;
        let b = busy.rma(0, 0, 300, OpKind::Get, 8_192).resume;
        assert!(b > q, "bg load must stretch serialization: {b} vs {q}");
    }
}
