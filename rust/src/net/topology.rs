//! Explicit link-level fabric topologies for the DES network.
//!
//! The flat model charges every cross-node op one `wire_ns` — fine while
//! the fabric is far from saturation (the paper's ≤640-rank testbeds),
//! useless for asking *where lock-free reads stop scaling once shared
//! links saturate*.  This module gives the network real links: a fabric
//! is a set of [`LinkCal`] occupancy calendars (one per directed link)
//! plus a deterministic routing function.  See DESIGN.md §13 for the cost
//! model, the calibration procedure against the flat model, and the
//! rules for when to trust large-scale extrapolations.
//!
//! Supported fabrics:
//!
//! * **Crossbar** — no explicit links; cross-node transit costs exactly
//!   `wire_ns`.  Bit-identical to the historical flat model, and the
//!   default everywhere.
//! * **Fat tree** — nodes grouped into pods under edge switches; pods
//!   joined by a core layer with `pod / oversub` uplinks per pod
//!   (`oversub` = the taper ratio; 2 ⇒ the common 2:1 oversubscribed
//!   HPC fabric).  Intra-pod routes take 2 links, inter-pod routes 4.
//! * **Dragonfly** — nodes grouped into groups with all-to-all global
//!   wiring: exactly one global link per group pair (the dragonfly's
//!   signature bottleneck).  Intra-group routes take 2 links, minimal
//!   inter-group routes 3 (the global link counts 2 hops of latency —
//!   global cables are long).

use crate::sim::Time;

/// Fabric shape connecting the simulated nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Full crossbar (the historical flat model): every node pair has
    /// dedicated capacity, transit is a constant `wire_ns`.
    Crossbar,
    /// Two-level fat tree.  `pod` = nodes per edge switch (0 = auto,
    /// `ceil(sqrt(nodes))`); `oversub` = core taper ratio (uplinks per
    /// pod = `max(1, pod / oversub)`).
    FatTree { pod: u32, oversub: u32 },
    /// One-dimensional dragonfly.  `group` = nodes per group (0 = auto,
    /// `ceil(sqrt(nodes))`); one global link per group pair.
    Dragonfly { group: u32 },
}

impl Topology {
    /// Parse a CLI spec: `flat` | `crossbar` | `fattree[:pod=P,oversub=S]`
    /// | `dragonfly[:group=G]`.
    pub fn parse(s: &str) -> Option<Self> {
        let (name, params) = match s.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (s, None),
        };
        let mut get = |key: &str| -> Option<u32> {
            params?
                .split(',')
                .filter_map(|kv| kv.split_once('='))
                .find(|(k, _)| *k == key)
                .and_then(|(_, v)| v.parse().ok())
        };
        match name {
            "flat" | "crossbar" => Some(Topology::Crossbar),
            "fattree" | "fat-tree" => Some(Topology::FatTree {
                pod: get("pod").unwrap_or(0),
                oversub: get("oversub").unwrap_or(2).max(1),
            }),
            "dragonfly" => {
                Some(Topology::Dragonfly { group: get("group").unwrap_or(0) })
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Topology::Crossbar => "crossbar",
            Topology::FatTree { .. } => "fattree",
            Topology::Dragonfly { .. } => "dragonfly",
        }
    }
}

/// How messages consume link capacity along a route.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkModel {
    /// Uncontended cut-through: per-hop latency plus one bottleneck
    /// serialization, no shared state.  Concurrent flows never interact.
    Constant,
    /// Store-and-forward over shared links: every link keeps a busy
    /// calendar ([`LinkCal`]), so concurrent flows queue and congestion
    /// emerges where routes overlap.
    Shared,
}

impl LinkModel {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "constant" | "const" => Some(LinkModel::Constant),
            "shared" => Some(LinkModel::Shared),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LinkModel::Constant => "constant",
            LinkModel::Shared => "shared",
        }
    }
}

/// Busy-interval calendar for one fabric link.
///
/// [`crate::sim::Resource`] assumes acquires arrive in non-decreasing
/// time order — true for per-node NICs and responders, whose acquire
/// instants derive from monotone per-node event streams.  A fabric link
/// is different: it receives request-path acquires (issue-side instants)
/// interleaved with response-path acquires (server exec instants, far
/// later once responders queue), so call order and arrival order
/// diverge wildly.  FIFO-by-call-order would let one late response
/// block requests that physically cleared the wire long before it —
/// inflating an *idle* fabric into a bottleneck.  The calendar instead
/// grants each flow the earliest idle gap at or after its arrival:
/// identical to FIFO when arrivals come in order, still physical when
/// they do not.
#[derive(Debug, Default)]
pub struct LinkCal {
    /// Sorted, disjoint busy intervals `(start, end)`, coalesced when
    /// they touch — a saturated link collapses to a handful of spans.
    busy: Vec<(Time, Time)>,
    busy_ns: u128,
    ops: u64,
}

impl LinkCal {
    /// Occupy the link for `occ` ns in the earliest idle gap starting
    /// at or after `now`; returns the completion instant.
    pub fn acquire(&mut self, now: Time, occ: Time) -> Time {
        self.ops += 1;
        if occ == 0 {
            return now;
        }
        self.busy_ns += occ as u128;
        // first busy interval ending after `now`
        let mut i = self.busy.partition_point(|&(_, e)| e <= now);
        let mut start = now;
        while let Some(&(s, e)) = self.busy.get(i) {
            if start + occ <= s {
                break; // the gap before interval `i` fits
            }
            start = start.max(e);
            i += 1;
        }
        let end = start + occ;
        // insert, coalescing with touching neighbours
        let merge_prev = i > 0 && self.busy[i - 1].1 == start;
        let merge_next =
            matches!(self.busy.get(i), Some(&(s, _)) if s == end);
        match (merge_prev, merge_next) {
            (true, true) => {
                self.busy[i - 1].1 = self.busy[i].1;
                self.busy.remove(i);
            }
            (true, false) => self.busy[i - 1].1 = end,
            (false, true) => self.busy[i].0 = start,
            (false, false) => self.busy.insert(i, (start, end)),
        }
        end
    }

    /// Fraction of `[0, horizon]` the link spent transmitting.
    pub fn utilization(&self, horizon: Time) -> f64 {
        if horizon == 0 {
            0.0
        } else {
            self.busy_ns as f64 / horizon as f64
        }
    }

    /// Messages that crossed this link.
    pub fn ops(&self) -> u64 {
        self.ops
    }
}

/// One directed link: its occupancy calendar plus a diagnostic label.
#[derive(Debug)]
pub struct Link {
    pub cal: LinkCal,
    pub label: String,
}

/// A route: up to 4 traversed links, each with its latency in hops.
#[derive(Clone, Copy, Debug, Default)]
pub struct Route {
    steps: [(u32, u32); 4],
    len: usize,
}

impl Route {
    fn push(&mut self, link: u32, hops: u32) {
        self.steps[self.len] = (link, hops);
        self.len += 1;
    }

    pub fn iter(&self) -> impl Iterator<Item = &(u32, u32)> {
        self.steps[..self.len].iter()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Resolved topology: concrete pod/group sizes for a node count.
#[derive(Debug)]
enum Resolved {
    Crossbar,
    FatTree { pod: u32, core_up: u32, nnodes: u32 },
    Dragonfly { group: u32, groups: u32, nnodes: u32 },
}

/// The instantiated fabric: links + deterministic routing.
#[derive(Debug)]
pub struct Fabric {
    pub links: Vec<Link>,
    kind: Resolved,
}

/// Deterministic static routing hash: flows between the same node pair
/// always ride the same core uplink (as real ECMP static hashing does),
/// so per-pair ordering is stable and runs are reproducible.
fn flow_hash(a: u32, b: u32) -> u32 {
    (a.wrapping_mul(0x9E37_79B1)) ^ (b.wrapping_mul(0x85EB_CA77))
}

/// Auto pod/group size: `ceil(sqrt(n))`, at least 2 once there are
/// multiple nodes (a 1-node "pod of 1" would make every route inter-pod).
fn auto_size(nnodes: u32) -> u32 {
    let mut s = (nnodes as f64).sqrt().ceil() as u32;
    if nnodes > 1 {
        s = s.max(2);
    }
    s.max(1)
}

impl Fabric {
    pub fn new(topology: Topology, nnodes: u32) -> Self {
        let mut links = Vec::new();
        let mut node_updown = |links: &mut Vec<Link>| {
            for n in 0..nnodes {
                links.push(Link {
                    cal: LinkCal::default(),
                    label: format!("n{n}.up"),
                });
                links.push(Link {
                    cal: LinkCal::default(),
                    label: format!("n{n}.down"),
                });
            }
        };
        let kind = match topology {
            Topology::Crossbar => Resolved::Crossbar,
            Topology::FatTree { pod, oversub } => {
                let pod = if pod == 0 { auto_size(nnodes) } else { pod.max(1) };
                let core_up = (pod / oversub.max(1)).max(1);
                node_updown(&mut links);
                let pods = nnodes.div_ceil(pod).max(1);
                for p in 0..pods {
                    for c in 0..core_up {
                        links.push(Link {
                            cal: LinkCal::default(),
                            label: format!("pod{p}.core{c}.up"),
                        });
                        links.push(Link {
                            cal: LinkCal::default(),
                            label: format!("pod{p}.core{c}.down"),
                        });
                    }
                }
                Resolved::FatTree { pod, core_up, nnodes }
            }
            Topology::Dragonfly { group } => {
                let group =
                    if group == 0 { auto_size(nnodes) } else { group.max(1) };
                let groups = nnodes.div_ceil(group).max(1);
                node_updown(&mut links);
                for a in 0..groups {
                    for b in (a + 1)..groups {
                        links.push(Link {
                            cal: LinkCal::default(),
                            label: format!("g{a}-g{b}.global"),
                        });
                    }
                }
                Resolved::Dragonfly { group, groups, nnodes }
            }
        };
        Self { links, kind }
    }

    /// Resolve the (deterministic, minimal) route between two distinct
    /// nodes.  Empty for the crossbar — its transit needs no links.
    pub fn route(&self, from: u32, to: u32) -> Route {
        debug_assert_ne!(from, to);
        let mut r = Route::default();
        match self.kind {
            Resolved::Crossbar => {}
            Resolved::FatTree { pod, core_up, nnodes } => {
                let (pf, pt) = (from / pod, to / pod);
                r.push(2 * from, 1); // node -> edge
                if pf != pt {
                    let c = flow_hash(from, to) % core_up;
                    let base = 2 * nnodes;
                    r.push(base + 2 * (pf * core_up + c), 1); // edge -> core
                    r.push(base + 2 * (pt * core_up + c) + 1, 1); // core -> edge
                }
                r.push(2 * to + 1, 1); // edge -> node
            }
            Resolved::Dragonfly { group, groups, nnodes } => {
                let (gf, gt) = (from / group, to / group);
                r.push(2 * from, 1); // node -> group router
                if gf != gt {
                    let (a, b) = (gf.min(gt), gf.max(gt));
                    // triangular index of the (a, b) group pair
                    let pair = a * groups - a * (a + 1) / 2 + (b - a - 1);
                    // global cables are long: 2 hops of latency
                    r.push(2 * nnodes + pair, 2);
                }
                r.push(2 * to + 1, 1); // group router -> node
            }
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(Topology::parse("flat"), Some(Topology::Crossbar));
        assert_eq!(Topology::parse("crossbar"), Some(Topology::Crossbar));
        assert_eq!(
            Topology::parse("fattree"),
            Some(Topology::FatTree { pod: 0, oversub: 2 })
        );
        assert_eq!(
            Topology::parse("fattree:pod=8,oversub=4"),
            Some(Topology::FatTree { pod: 8, oversub: 4 })
        );
        assert_eq!(
            Topology::parse("dragonfly:group=4"),
            Some(Topology::Dragonfly { group: 4 })
        );
        assert_eq!(Topology::parse("torus"), None);
        assert_eq!(LinkModel::parse("shared"), Some(LinkModel::Shared));
        assert_eq!(LinkModel::parse("constant"), Some(LinkModel::Constant));
        assert_eq!(LinkModel::parse("x"), None);
    }

    #[test]
    fn fat_tree_routes() {
        // 8 nodes, pods of 4, 2 core uplinks per pod
        let f = Fabric::new(Topology::FatTree { pod: 4, oversub: 2 }, 8);
        assert_eq!(f.links.len(), 2 * 8 + 2 * 2 * 2);
        // intra-pod: up(src), down(dst)
        let r = f.route(0, 3);
        let steps: Vec<u32> = r.iter().map(|&(l, _)| l).collect();
        assert_eq!(steps, vec![0, 7]);
        // inter-pod: 4 links, through the core layer
        let r = f.route(0, 5);
        assert_eq!(r.len(), 4);
        let steps: Vec<u32> = r.iter().map(|&(l, _)| l).collect();
        assert_eq!(steps[0], 0); // n0.up
        assert!(f.links[steps[1] as usize].label.starts_with("pod0.core"));
        assert!(f.links[steps[2] as usize].label.starts_with("pod1.core"));
        assert_eq!(steps[3], 11); // n5.down
        // static routing: same pair, same route
        let again: Vec<u32> = f.route(0, 5).iter().map(|&(l, _)| l).collect();
        assert_eq!(steps, again);
    }

    #[test]
    fn dragonfly_routes() {
        // 6 nodes, groups of 2 -> 3 groups, 3 global links
        let f = Fabric::new(Topology::Dragonfly { group: 2 }, 6);
        assert_eq!(f.links.len(), 2 * 6 + 3);
        let r = f.route(0, 1); // same group
        assert_eq!(r.len(), 2);
        let r = f.route(0, 5); // group 0 -> group 2
        assert_eq!(r.len(), 3);
        let steps: Vec<(u32, u32)> = r.iter().cloned().collect();
        assert_eq!(f.links[steps[1].0 as usize].label, "g0-g2.global");
        assert_eq!(steps[1].1, 2); // long global cable: 2 hops
    }

    #[test]
    fn link_calendar_is_fifo_for_in_order_arrivals() {
        let mut l = LinkCal::default();
        assert_eq!(l.acquire(0, 10), 10);
        assert_eq!(l.acquire(5, 10), 20); // queues behind the first
        assert_eq!(l.acquire(20, 10), 30); // back-to-back
        assert_eq!(l.acquire(100, 10), 110); // idle gap: starts on time
        assert_eq!(l.ops(), 4);
        assert!((l.utilization(100) - 0.4).abs() < 1e-9);
    }

    #[test]
    fn link_calendar_late_acquire_does_not_block_earlier_arrival() {
        // a response-path acquire far in the future must not delay a
        // request that physically reaches the link before it
        let mut l = LinkCal::default();
        assert_eq!(l.acquire(10_000, 50), 10_050);
        assert_eq!(l.acquire(0, 50), 50); // fits in the idle prefix
        // a flow that doesn't fit before the booked span queues after it
        assert_eq!(l.acquire(9_990, 50), 10_100);
        // zero occupancy (sub-ns serialization) passes through untouched
        assert_eq!(l.acquire(3, 0), 3);
    }

    #[test]
    fn link_calendar_coalesces_touching_spans() {
        let mut l = LinkCal::default();
        l.acquire(0, 10);
        l.acquire(30, 10);
        l.acquire(10, 10); // bridges neither (ends at 20 < 30)
        l.acquire(20, 10); // bridges [0,30) and [30,40) into one span
        assert_eq!(l.busy.len(), 1);
        assert_eq!(l.busy[0], (0, 40));
        assert_eq!(l.acquire(0, 5), 45); // whole span is solid
    }

    #[test]
    fn auto_sizing() {
        assert_eq!(auto_size(1), 1);
        assert_eq!(auto_size(2), 2);
        assert_eq!(auto_size(32), 6);
        let f = Fabric::new(Topology::FatTree { pod: 0, oversub: 2 }, 32);
        // pod 6 -> 6 pods, 3 core uplinks each
        assert_eq!(f.links.len(), 2 * 32 + 6 * 2 * 3);
    }
}
