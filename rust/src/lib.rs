//! # mpi-dht
//!
//! A fast distributed hash-table as surrogate model for HPC applications —
//! a full reproduction of Lübke, De Lucia, Petri & Schnor (ICCS/CS.DC
//! 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! * **L3 (this crate)** — the paper's contribution: three MPI-RMA DHT
//!   designs ([`dht`]), the DAOS-like server baseline ([`daos`]), the POET
//!   reactive-transport coordinator ([`poet`], [`coordinator`]), a
//!   protocol-accurate discrete-event cluster ([`rma::sim`], [`net`]) and
//!   a threaded shared-memory backend ([`rma::shm`]) — both behind the
//!   [`rma::RmaBackend`] trait, whose pipelined batch execution layer
//!   (`Dht::read_batch`/`Dht::write_batch`, DESIGN.md §3) keeps many
//!   one-sided ops in flight per rank.  Beyond the paper, the *elastic
//!   capacity* subsystem ([`dht::migrate`], DESIGN.md §8) resizes the
//!   table online with live, lock-free cooperative migration.
//! * **L2/L1 (python/, build time only)** — the geochemistry model and its
//!   Pallas kernels, AOT-lowered to HLO text artifacts.
//! * **runtime** — [`runtime`] loads the artifacts via PJRT and executes
//!   them from the Rust request path (Python is never on it).
//!
//! See README.md for the tour, DESIGN.md for the architecture and
//! EXPERIMENTS.md for measured results vs. the paper.

#![deny(rustdoc::broken_intra_doc_links)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod daos;
pub mod dht;
pub mod metrics;
pub mod net;
pub mod poet;
pub mod rma;
pub mod runtime;
pub mod sim;
pub mod util;
