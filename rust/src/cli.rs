//! Hand-rolled CLI argument parsing (no `clap` offline).
//!
//! Conventions: `--key value` or `--key=value` options, bare `--switch`
//! flags, positional arguments in order.  Subcommands are the first
//! positional argument (see `main.rs`).

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    switches: HashSet<String>,
}

/// Option names that take a value (everything else starting with `--` is
/// treated as a boolean switch).
const VALUED: &[&str] = &[
    "--ranks", "--ops", "--dist", "--variant", "--mode", "--profile",
    "--ny", "--nx", "--steps", "--workers", "--digits", "--dt",
    "--engine", "--artifacts", "--win-bytes", "--seed", "--config",
    "--set", "--clients", "--out", "--repeats", "--read-percent",
    "--zipf-range", "--theta", "--grid", "--pipeline",
    "--resize-at-iter", "--resize-factor", "--replicas", "--kill-rank",
    "--kill-rank-at", "--digits-ladder", "--ladder-tol", "--l1-bytes",
    "--tol", "--label", "--revive-rank-at", "--retry-budget",
    "--backoff-base-us", "--kill-at-iter", "--kill-worker",
    "--revive-at-iter", "--topology", "--link-model", "--bg-traffic",
    "--tenants", "--evict", "--tenant-mix", "--tenant-phase",
];

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> Result<Self> {
        let mut a = Args::default();
        let mut it = argv.peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.options.insert(format!("--{k}"), v.to_string());
                } else if VALUED.contains(&tok.as_str()) {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("{tok} expects a value"))?;
                    if tok == "--set" {
                        // --set may repeat; accumulate with ';'
                        a.options
                            .entry(tok.clone())
                            .and_modify(|old| {
                                old.push(';');
                                old.push_str(&v);
                            })
                            .or_insert(v);
                    } else {
                        a.options.insert(tok, v);
                    }
                } else {
                    a.switches.insert(tok);
                }
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.contains(switch)
    }

    pub fn get(&self, opt: &str) -> Option<&str> {
        self.options.get(opt).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, opt: &str, default: &'a str) -> &'a str {
        self.get(opt).unwrap_or(default)
    }

    pub fn u64_or(&self, opt: &str, default: u64) -> Result<u64> {
        match self.get(opt) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|_| anyhow!("{opt}: expected integer, got {v:?}")),
        }
    }

    pub fn usize_or(&self, opt: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(opt, default as u64)? as usize)
    }

    pub fn f64_or(&self, opt: &str, default: f64) -> Result<f64> {
        match self.get(opt) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("{opt}: expected float, got {v:?}")),
        }
    }

    /// Comma/range list: "128,256" or "12..72:12" (start..end:step).
    pub fn u32_list_or(&self, opt: &str, default: &[u32]) -> Result<Vec<u32>> {
        let Some(spec) = self.get(opt) else {
            return Ok(default.to_vec());
        };
        if let Some((range, step)) = spec.split_once(':') {
            let (a, b) = range
                .split_once("..")
                .ok_or_else(|| anyhow!("{opt}: expected a..b:step"))?;
            let (a, b, s): (u32, u32, u32) =
                (a.parse()?, b.parse()?, step.parse()?);
            if s == 0 {
                return Err(anyhow!("{opt}: step must be > 0"));
            }
            return Ok((a..=b).step_by(s as usize).collect());
        }
        spec.split(',')
            .filter(|t| !t.is_empty())
            .map(|t| t.parse().map_err(|_| anyhow!("{opt}: bad entry {t:?}")))
            .collect()
    }

    /// All `--set key=value` overrides.
    pub fn overrides(&self) -> Vec<&str> {
        self.get("--set").map(|s| s.split(';').collect()).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_options_switches() {
        let a = parse(&[
            "bench-kv", "--ranks", "128,256", "--variant=lockfree",
            "--paper-scale",
        ]);
        assert_eq!(a.positional, vec!["bench-kv"]);
        assert_eq!(a.get("--ranks"), Some("128,256"));
        assert_eq!(a.get("--variant"), Some("lockfree"));
        assert!(a.has("--paper-scale"));
        assert!(!a.has("--other"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["x", "--ops", "5_000", "--dt", "2000.0"]);
        assert_eq!(a.u64_or("--ops", 0).unwrap(), 5000);
        assert_eq!(a.f64_or("--dt", 0.0).unwrap(), 2000.0);
        assert_eq!(a.u64_or("--missing", 9).unwrap(), 9);
        assert!(a.u64_or("--dt", 0).is_err());
    }

    #[test]
    fn rank_lists() {
        let a = parse(&["x", "--ranks", "128,256,384"]);
        assert_eq!(a.u32_list_or("--ranks", &[]).unwrap(), vec![128, 256, 384]);
        let a = parse(&["x", "--ranks", "12..72:12"]);
        assert_eq!(
            a.u32_list_or("--ranks", &[]).unwrap(),
            vec![12, 24, 36, 48, 60, 72]
        );
        let a = parse(&["x"]);
        assert_eq!(a.u32_list_or("--ranks", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn approx_lookup_flags_take_values() {
        let a = parse(&[
            "poet-des", "--digits-ladder", "2", "--ladder-tol", "5e-3",
            "--l1-bytes", "1048576",
        ]);
        assert_eq!(a.u64_or("--digits-ladder", 0).unwrap(), 2);
        assert_eq!(a.f64_or("--ladder-tol", 0.0).unwrap(), 5e-3);
        assert_eq!(a.usize_or("--l1-bytes", 0).unwrap(), 1048576);
    }

    #[test]
    fn chaos_flags_take_values_and_repair_is_a_switch() {
        let a = parse(&[
            "poet-des", "--kill-rank", "3", "--kill-rank-at", "0.4",
            "--revive-rank-at", "0.8", "--retry-budget", "5",
            "--backoff-base-us", "20", "--repair",
        ]);
        assert_eq!(a.u64_or("--kill-rank", 0).unwrap(), 3);
        assert_eq!(a.f64_or("--revive-rank-at", 0.0).unwrap(), 0.8);
        assert_eq!(a.u64_or("--retry-budget", 0).unwrap(), 5);
        assert_eq!(a.f64_or("--backoff-base-us", 0.0).unwrap(), 20.0);
        assert!(a.has("--repair"));
    }

    #[test]
    fn topology_flags_take_values() {
        let a = parse(&[
            "bench-kv", "--topology", "fattree:pod=8,oversub=4",
            "--link-model", "shared", "--bg-traffic", "0.5",
        ]);
        assert_eq!(a.get("--topology"), Some("fattree:pod=8,oversub=4"));
        assert_eq!(a.get("--link-model"), Some("shared"));
        assert_eq!(a.f64_or("--bg-traffic", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn tenant_flags_take_values() {
        let a = parse(&[
            "bench-kv", "--tenants", "4", "--evict", "second-chance",
            "--tenant-mix", "flood,hotread", "--tenant-phase", "8",
        ]);
        assert_eq!(a.u64_or("--tenants", 1).unwrap(), 4);
        assert_eq!(a.get("--evict"), Some("second-chance"));
        assert_eq!(a.get("--tenant-mix"), Some("flood,hotread"));
        assert_eq!(a.usize_or("--tenant-phase", 0).unwrap(), 8);
    }

    #[test]
    fn repeated_set_accumulates() {
        let a = parse(&["x", "--set", "a=1", "--set", "b=2"]);
        assert_eq!(a.overrides(), vec!["a=1", "b=2"]);
    }

    #[test]
    fn missing_value_is_an_error() {
        assert!(Args::parse(["x", "--ranks"].iter().map(|s| s.to_string()))
            .is_err());
    }
}
