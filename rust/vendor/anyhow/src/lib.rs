//! Minimal, dependency-free subset of the `anyhow` API, vendored for the
//! offline build environment (see DESIGN.md §Build).
//!
//! Supported surface (everything this workspace uses):
//! `Error`, `Result<T>`, `anyhow!`, `bail!`, `ensure!`, and the `Context`
//! extension trait on `Result` and `Option`.  `Error` captures the full
//! source chain as strings; `{}` prints the outermost message and `{:#}`
//! the whole chain joined with `": "`, matching `anyhow`'s behaviour.

use std::fmt;

/// A string-chained error value (outermost context first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context layer (what `Context::context` attaches).
    fn wrap(mut self, context: String) -> Self {
        self.chain.insert(0, context);
        self
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) cause's message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

/// Attach context to errors (and to `None`), like `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(anyhow!("boom {}", 7))
    }

    #[test]
    fn display_and_alternate() {
        let e = fails().unwrap_err().wrap("outer".into());
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: boom 7");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn parse() -> Result<i64> {
            let v: i64 = "nope".parse()?;
            Ok(v)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::num::ParseIntError> =
            "x".parse::<i64>().map(|_| ());
        let e = r.context("reading count").unwrap_err();
        assert_eq!(format!("{e}"), "reading count");
        let n: Option<u32> = None;
        assert!(n.with_context(|| "missing").is_err());
        // context on an already-anyhow Result
        let e2: Result<()> = Err(anyhow!("inner"));
        assert_eq!(format!("{:#}", e2.context("outer").unwrap_err()),
                   "outer: inner");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(v: u32) -> Result<u32> {
            ensure!(v < 10, "too big: {v}");
            if v == 3 {
                bail!("three is right out");
            }
            Ok(v)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).is_err());
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big: 11");
    }
}
