//! Minimal CRC32 (IEEE 802.3 polynomial, reflected) exposing the
//! `crc32fast::Hasher` API surface this workspace uses.  Vendored for the
//! offline build environment; the DHT protocol only requires *a* fixed
//! 32-bit checksum (see `dht::bucket::record_crc`), and this computes the
//! standard CRC32 so results match the real `crc32fast` crate if it is
//! ever swapped back in.

const POLY: u32 = 0xEDB8_8320;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Streaming CRC32 hasher (API-compatible subset of `crc32fast::Hasher`).
#[derive(Clone, Debug)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher {
    pub fn new() -> Self {
        Hasher { state: !0 }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    pub fn finalize(self) -> u32 {
        !self.state
    }
}

/// One-shot convenience (`crc32fast::hash`).
pub fn hash(bytes: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(bytes);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // standard CRC32 ("123456789") = 0xCBF43926
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        // streaming equals one-shot
        let mut h = Hasher::new();
        h.update(b"1234");
        h.update(b"56789");
        assert_eq!(h.finalize(), 0xCBF4_3926);
    }

    #[test]
    fn detects_single_byte_changes() {
        let a = hash(b"hello world");
        let b = hash(b"hellp world");
        assert_ne!(a, b);
    }
}
