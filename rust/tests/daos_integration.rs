//! DAOS baseline integration: KV semantics through the DES RPC path and
//! the architectural throughput characteristics of Fig. 3.

use mpi_dht::bench::{run_daos, run_kv, Dist, KvCfg, Mode};
use mpi_dht::daos::DaosConfig;
use mpi_dht::dht::Variant;
use mpi_dht::net::NetConfig;

fn cfg(clients: u32, ops: u64) -> KvCfg {
    let mut c = KvCfg::new(clients, ops, Dist::Uniform, Mode::WriteThenRead);
    c.seed = 4242;
    c
}

#[test]
fn daos_serves_all_reads_written() {
    let res = run_daos(NetConfig::turing_roce(), DaosConfig::default(), cfg(8, 500));
    // the central server holds a real HashMap: zero misses, ever
    assert!(res.read_mops > 0.0 && res.write_mops > 0.0);
    // latencies must sit in the paper's bands (§3.4): reads 56-198 µs
    assert!(
        (40_000..260_000).contains(&res.read_lat_p50),
        "read p50 {} ns",
        res.read_lat_p50
    );
    // writes 157-698 µs
    assert!(
        (120_000..900_000).contains(&res.write_lat_p50),
        "write p50 {} ns",
        res.write_lat_p50
    );
}

#[test]
fn daos_throughput_flat_with_clients() {
    // the server serializes processing: beyond saturation, more clients
    // do not add throughput (Fig. 3's flat DAOS curves)
    let lo = run_daos(NetConfig::turing_roce(), DaosConfig::default(), cfg(24, 2_000));
    let hi = run_daos(NetConfig::turing_roce(), DaosConfig::default(), cfg(72, 2_000));
    let growth = hi.read_mops / lo.read_mops;
    assert!(
        growth < 2.0,
        "DAOS reads should saturate: {} -> {} Mops",
        lo.read_mops,
        hi.read_mops
    );
    // near the paper's ceilings: ~0.36 Mops reads, ~0.10 Mops writes
    assert!((0.15..0.6).contains(&hi.read_mops), "{}", hi.read_mops);
    assert!((0.05..0.2).contains(&hi.write_mops), "{}", hi.write_mops);
}

#[test]
fn dht_beats_daos_by_paper_factors() {
    // paper §3.4: improvement factors 8.2-12.5 (read), 10.1-15.3 (write)
    for clients in [24u32, 48] {
        let daos =
            run_daos(NetConfig::turing_roce(), DaosConfig::default(), cfg(clients, 8_000));
        let dht = run_kv(Variant::Coarse, NetConfig::turing_roce(), cfg(clients, 8_000));
        let rf = dht.read_mops / daos.read_mops;
        let wf = dht.write_mops / daos.write_mops;
        assert!((3.0..30.0).contains(&rf), "read factor {rf} at {clients}");
        assert!((4.0..35.0).contains(&wf), "write factor {wf} at {clients}");
    }
}

#[test]
fn coarse_dht_peaks_in_paper_band_on_turing() {
    // paper: MPI-DHT peaks at 4.12 M reads / 1.45 M writes per second
    let res = run_kv(Variant::Coarse, NetConfig::turing_roce(), cfg(48, 3_000));
    assert!(
        (1.0..8.0).contains(&res.read_mops),
        "coarse reads at 48 clients: {} Mops",
        res.read_mops
    );
    assert!(
        (0.4..3.0).contains(&res.write_mops),
        "coarse writes at 48 clients: {} Mops",
        res.write_mops
    );
    // latency bands (§3.4): reads 4-17 µs, writes 13-57 µs
    assert!(
        (2_000..30_000).contains(&res.read_lat_p50),
        "read p50 {}",
        res.read_lat_p50
    );
    assert!(
        (8_000..90_000).contains(&res.write_lat_p50),
        "write p50 {}",
        res.write_lat_p50
    );
}
