//! RMA backend integration: threaded atomicity, DES determinism, and the
//! torn-read machinery that motivates the lock-free DHT's checksums.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use mpi_dht::rma::shm::ShmCluster;
use mpi_dht::rma::sim::SimCluster;
use mpi_dht::rma::{OpSm, Req, Resp, SmStep, WorkItem, Workload};
use mpi_dht::net::{NetConfig, Network};
use mpi_dht::sim::Time;

// ---------------------------------------------------------------- threaded

/// A tiny SM that runs one request and returns the response.
struct OneReq(Option<Req>, Option<Resp>);

impl OpSm for OneReq {
    type Out = Resp;
    fn step(&mut self, resp: Resp) -> SmStep<Resp> {
        match self.0.take() {
            Some(r) => SmStep::Issue(r),
            None => SmStep::Done(resp),
        }
    }
}

fn do_req(rma: &mpi_dht::rma::shm::ShmRma, req: Req) -> Resp {
    rma.exec(&mut OneReq(Some(req), None))
}

#[test]
fn concurrent_fao_is_lossless() {
    let cluster = ShmCluster::new(2, 256);
    let mut threads = Vec::new();
    for t in 0..4u32 {
        let rma = cluster.rma(t % 2);
        threads.push(std::thread::spawn(move || {
            for _ in 0..5_000 {
                do_req(&rma, Req::Fao { target: 0, offset: 16, add: 1 });
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let rma = cluster.rma(0);
    assert_eq!(rma.peek_word(0, 16), 20_000);
}

#[test]
fn concurrent_cas_single_winner_per_round() {
    let cluster = ShmCluster::new(1, 64);
    let wins = Arc::new(AtomicU64::new(0));
    let mut threads = Vec::new();
    for _ in 0..4 {
        let rma = cluster.rma(0);
        let wins = Arc::clone(&wins);
        threads.push(std::thread::spawn(move || {
            for round in 0..1_000u64 {
                if let Resp::Word(prev) = do_req(
                    &rma,
                    Req::Cas {
                        target: 0,
                        offset: 0,
                        expected: round,
                        desired: round + 1,
                    },
                ) {
                    if prev == round {
                        wins.fetch_add(1, Ordering::Relaxed);
                    }
                }
                // spin until the round advances
                while match do_req(&rma, Req::Get { target: 0, offset: 0, len: 8 })
                {
                    Resp::Data(d) => {
                        u64::from_le_bytes(d.try_into().unwrap()) <= round
                    }
                    _ => false,
                } {
                    std::thread::yield_now();
                }
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    // each round has exactly one CAS winner
    assert_eq!(wins.load(Ordering::Relaxed), 1_000);
}

// --------------------------------------------------------------------- DES

/// Workload: one writer hammers a bucket with alternating patterns while
/// one reader polls it; the reader must eventually observe a torn record
/// (prefix of the new write, suffix of the old) — the race the lock-free
/// DHT's CRC detects.
struct TornProbe {
    writer_ops: u64,
    reader_ops: u64,
    pub torn_seen: u64,
    launched: [u64; 2],
}

enum ProbeSm {
    Write(u64),
    Read,
    AwaitWrite,
    AwaitRead,
}

impl OpSm for ProbeSm {
    type Out = Option<Vec<u8>>;
    fn step(&mut self, resp: Resp) -> SmStep<Option<Vec<u8>>> {
        match std::mem::replace(self, ProbeSm::AwaitWrite) {
            ProbeSm::Write(pat) => {
                *self = ProbeSm::AwaitWrite;
                SmStep::Issue(Req::Put {
                    target: 0,
                    offset: 0,
                    data: vec![pat as u8; 512],
                })
            }
            ProbeSm::Read => {
                *self = ProbeSm::AwaitRead;
                SmStep::Issue(Req::Get { target: 0, offset: 0, len: 512 })
            }
            ProbeSm::AwaitWrite => SmStep::Done(None),
            ProbeSm::AwaitRead => match resp {
                Resp::Data(d) => SmStep::Done(Some(d)),
                other => panic!("unexpected {other:?}"),
            },
        }
    }
}

impl Workload for TornProbe {
    type Sm = ProbeSm;

    fn next(&mut self, rank: u32, _lane: u32, _now: Time) -> WorkItem<ProbeSm> {
        match rank {
            0 if self.launched[0] < self.writer_ops => {
                self.launched[0] += 1;
                WorkItem::Op(ProbeSm::Write(self.launched[0]))
            }
            1 if self.launched[1] < self.reader_ops => {
                self.launched[1] += 1;
                WorkItem::Op(ProbeSm::Read)
            }
            _ => WorkItem::Finished,
        }
    }

    fn on_complete(
        &mut self,
        _rank: u32,
        _lane: u32,
        _now: Time,
        _lat: Time,
        out: Option<Vec<u8>>,
    ) {
        if let Some(d) = out {
            let first = d[0];
            if d.iter().any(|&b| b != first) {
                self.torn_seen += 1;
            }
        }
    }
}

#[test]
fn des_models_torn_reads() {
    let net = Network::new(NetConfig::pik_ndr(), 256);
    // rank 1 reads from node 0's window while rank 0 writes it; both on
    // the same node keeps latencies tight so overlaps happen
    let mut cluster = SimCluster::new(
        TornProbe {
            writer_ops: 20_000,
            reader_ops: 20_000,
            torn_seen: 0,
            launched: [0, 0],
        },
        net,
        256,
        1024,
    );
    cluster.run();
    assert!(
        cluster.workload.torn_seen > 0,
        "no torn reads observed in 20k overlapping accesses"
    );
    // torn reads must be rare relative to total reads (paper Tab. 2:
    // mismatch rates around 1e-5..1e-3)
    assert!(
        (cluster.workload.torn_seen as f64) < 0.25 * 20_000.0,
        "torn reads implausibly common: {}",
        cluster.workload.torn_seen
    );
}

#[test]
fn des_is_deterministic() {
    let run = || {
        let net = Network::new(NetConfig::pik_ndr(), 64);
        let mut cluster = SimCluster::new(
            TornProbe {
                writer_ops: 2_000,
                reader_ops: 2_000,
                torn_seen: 0,
                launched: [0, 0],
            },
            net,
            64,
            1024,
        );
        let rep = cluster.run();
        (rep.duration, rep.ops, rep.net_messages, cluster.workload.torn_seen)
    };
    assert_eq!(run(), run(), "same seed/workload must replay identically");
}
