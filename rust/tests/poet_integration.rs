//! POET integration: physics equivalence across engines and execution
//! modes, cache-accuracy trade-off, conservation.

use std::sync::Arc;

use mpi_dht::coordinator::{build_poet, EngineKind};
use mpi_dht::dht::Variant;
use mpi_dht::poet::{NativeChemistry, PoetConfig, PoetDriver};

fn tiny_cfg() -> PoetConfig {
    let mut cfg = PoetConfig::small();
    cfg.ny = 10;
    cfg.nx = 30;
    cfg.steps = 25;
    cfg.inj_rows = 2;
    cfg.cf = [0.5, 0.0];
    cfg.workers = 1;
    cfg
}

/// PJRT chemistry and the native mirror produce the same trajectory
/// (requires built artifacts; skipped otherwise).
#[test]
fn pjrt_and_native_drivers_agree() {
    if !mpi_dht::runtime::Engine::available()
        || !mpi_dht::runtime::Engine::default_dir()
            .join("manifest.txt")
            .exists()
    {
        eprintln!("skipping: PJRT runtime or artifacts not available");
        return;
    }
    let cfg = tiny_cfg();
    let mut native = PoetDriver::with_default_waters(
        cfg.clone(),
        Arc::new(NativeChemistry),
    );
    native.run_reference();
    let mut pjrt = build_poet(cfg, EngineKind::Pjrt).expect("pjrt driver");
    pjrt.run_reference();
    let mut max_d: f64 = 0.0;
    for (a, b) in native.grid.solutes.iter().zip(pjrt.grid.solutes.iter()) {
        max_d = max_d.max((a - b).abs() / a.abs().max(1e-12));
    }
    for (a, b) in native.grid.minerals.iter().zip(pjrt.grid.minerals.iter()) {
        max_d = max_d.max((a - b).abs() / a.abs().max(1e-12));
    }
    assert!(max_d < 1e-9, "engines diverged: rel {max_d}");
}

/// The surrogate-cached run converges to the reference as rounding digits
/// increase (the paper's accuracy/performance trade-off, §5.4).
#[test]
fn accuracy_improves_with_digits() {
    let mut reference =
        PoetDriver::with_default_waters(tiny_cfg(), Arc::new(NativeChemistry));
    reference.run_reference();

    let mut errs = Vec::new();
    for digits in [2u32, 4, 7] {
        let mut cfg = tiny_cfg();
        cfg.digits = digits;
        let mut d =
            PoetDriver::with_default_waters(cfg, Arc::new(NativeChemistry));
        d.run_with_dht(Variant::LockFree);
        let err: f64 = d
            .grid
            .minerals
            .iter()
            .zip(reference.grid.minerals.iter())
            .map(|(a, b)| (a - b).abs())
            .sum();
        errs.push(err);
    }
    assert!(
        errs[2] <= errs[0] + 1e-12,
        "7-digit error {} should not exceed 2-digit error {}",
        errs[2],
        errs[0]
    );
}

/// Mass balance: total (dissolved + mineral) calcium only changes through
/// the boundaries; with zero inflow/outflow difference it is conserved by
/// chemistry alone.
#[test]
fn chemistry_conserves_calcium_without_transport() {
    let cfg = tiny_cfg();
    let mut d =
        PoetDriver::with_default_waters(cfg, Arc::new(NativeChemistry));
    // disable transport by zero CFL: chemistry-only evolution
    d.cfg.cf = [0.0, 0.0];
    let before = d.grid.total_ca();
    d.run_reference();
    let after = d.grid.total_ca();
    assert!(
        ((after - before) / before).abs() < 1e-9,
        "calcium not conserved: {before} -> {after}"
    );
}

/// All three variants used as cache produce the same physics as the
/// reference at matching rounding (no torn data may leak into the grid).
#[test]
fn all_variants_preserve_physics() {
    let mut reference =
        PoetDriver::with_default_waters(tiny_cfg(), Arc::new(NativeChemistry));
    let ref_stats = reference.run_reference();
    for variant in Variant::ALL {
        let mut cfg = tiny_cfg();
        cfg.workers = 2;
        let mut d =
            PoetDriver::with_default_waters(cfg, Arc::new(NativeChemistry));
        let stats = d.run_with_dht(variant);
        assert!(stats.hit_rate() > 0.3, "{variant:?} hit {}", stats.hit_rate());
        let d_dol = (stats.max_dolomite - ref_stats.max_dolomite).abs();
        assert!(
            d_dol <= 0.35 * ref_stats.max_dolomite.max(1e-12),
            "{variant:?}: dolomite {} vs ref {}",
            stats.max_dolomite,
            ref_stats.max_dolomite
        );
    }
}

/// DES POET at several rank counts: reference runtime must not *improve*
/// super-linearly and the lock-free gain must shrink with rank count
/// (Fig. 7's shape).
#[test]
fn des_poet_gain_shrinks_with_ranks() {
    use mpi_dht::net::NetConfig;
    use mpi_dht::poet::desmodel::{run_poet_des, PoetDesCfg};

    let mut gains = Vec::new();
    for nranks in [16u32, 64] {
        let mut c = PoetDesCfg::scaled(nranks, None);
        c.ny = 16;
        c.nx = 48;
        c.steps = 50;
        c.inj_rows = 4;
        let refr = run_poet_des(c.clone(), NetConfig::pik_ndr());
        let mut c = PoetDesCfg::scaled(nranks, Some(Variant::LockFree));
        c.ny = 16;
        c.nx = 48;
        c.steps = 50;
        c.inj_rows = 4;
        let lf = run_poet_des(c, NetConfig::pik_ndr());
        gains.push(1.0 - lf.runtime_s / refr.runtime_s);
    }
    assert!(
        gains[0] > gains[1] - 0.05,
        "gain should shrink with ranks: {gains:?}"
    );
    assert!(gains[0] > 0.0, "lock-free must help at small scale: {gains:?}");
}
