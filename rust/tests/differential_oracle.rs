//! Differential test oracle for the four DHT variants (DESIGN.md §12).
//!
//! Random op schedules (`G::schedule`) are replayed — sequentially, so
//! the interleaving itself is deterministic — against every variant on
//! both backends (threaded shm and DES).  Writes follow memoization
//! semantics (the surrogate use case): the value of a key is a pure
//! function of the key, and a "write" is read-then-write-on-miss.
//!
//! Invariants checked per schedule:
//!
//! * every replay produces the *identical* trace (read results, write
//!   outcomes, final live table contents) — the variants differ only in
//!   their consistency mechanism, never in visible semantics;
//! * a read hit always returns the reference value `value_for(id)` and
//!   never fires for a key the reference model has not seen written;
//! * the final table (via [`DhtCheckpoint::capture`]) is a subset of the
//!   reference contents (cache semantics: eviction may drop entries,
//!   corruption of live data must not occur).
//!
//! Failures print the generator seed; replay with `MPI_DHT_PROP_SEED`.

use std::collections::{HashMap, HashSet};

use mpi_dht::bench::keys::{key_for, value_for};
use mpi_dht::dht::{
    BucketLayout, Dht, DhtCheckpoint, DhtOutcome, EvictPolicy, Meta, Variant,
};
use mpi_dht::net::{NetConfig, Network};
use mpi_dht::rma::RmaBackend;
use mpi_dht::util::prop::{prop_check, SchedOp};
use mpi_dht::{prop_assert, prop_assert_eq};

const KEY_LEN: usize = 16;
const VAL_LEN: usize = 24;
const NRANKS: u32 = 4;
const BUCKETS_PER_RANK: usize = 24;

/// Window bytes giving every variant the *same* bucket count — bucket
/// sizes differ (locks, CRC), and equal addressing is what makes the
/// four variants probe and evict identically.
fn win_bytes(variant: Variant) -> usize {
    BUCKETS_PER_RANK * BucketLayout::new(variant, KEY_LEN, VAL_LEN).size()
}

/// What one replay observed, in schedule order.
#[derive(Clone, PartialEq, Eq, Debug)]
struct Trace {
    /// Result of every read (including the memoization probe reads).
    reads: Vec<Option<Vec<u8>>>,
    /// Discriminant of every write outcome (255 = memoized, no write).
    writes: Vec<u8>,
    /// Final live entries, sorted.
    entries: Vec<(Vec<u8>, Vec<u8>)>,
}

fn disc(out: &DhtOutcome) -> u8 {
    match out {
        DhtOutcome::ReadHit(_) => 0,
        DhtOutcome::ReadMiss => 1,
        DhtOutcome::ReadCorrupt => 2,
        DhtOutcome::WriteFresh => 3,
        DhtOutcome::WriteUpdate => 4,
        DhtOutcome::WriteEvict => 5,
    }
}

/// Replay `sched` on a fresh cluster.  Consecutive same-rank reads are
/// issued through `read_batch` (exercising the pipelined epoch and its
/// batch boundaries); writes go through the memoization path one by one.
fn replay<B: RmaBackend>(handles: &mut [Dht<B>], sched: &[SchedOp]) -> Trace {
    let mut t = Trace { reads: Vec::new(), writes: Vec::new(), entries: Vec::new() };
    let mut i = 0;
    while i < sched.len() {
        let op = sched[i];
        let mut j = i + 1;
        while j < sched.len()
            && j - i < 4
            && sched[j].rank == op.rank
            && sched[j].read == op.read
        {
            j += 1;
        }
        let h = &mut handles[op.rank as usize];
        if op.read {
            let keys: Vec<Vec<u8>> =
                sched[i..j].iter().map(|o| key_for(o.id, KEY_LEN)).collect();
            t.reads.extend(h.read_batch(&keys));
        } else {
            for o in &sched[i..j] {
                let key = key_for(o.id, KEY_LEN);
                let probe = h.read(&key);
                let memoized = probe.is_some();
                t.reads.push(probe);
                if memoized {
                    t.writes.push(255);
                } else {
                    let val = value_for(o.id, VAL_LEN);
                    t.writes.push(disc(&h.write(&key, &val)));
                }
            }
        }
        i = j;
    }
    t.entries = DhtCheckpoint::capture(handles).entries;
    t.entries.sort();
    t
}

fn replay_shm(variant: Variant, sched: &[SchedOp]) -> Trace {
    let mut handles =
        Dht::create(variant, NRANKS, win_bytes(variant), KEY_LEN, VAL_LEN);
    replay(&mut handles, sched)
}

fn replay_des(variant: Variant, sched: &[SchedOp]) -> Trace {
    let net = Network::new(NetConfig::pik_ndr(), NRANKS);
    let mut handles = Dht::create_sim(
        variant,
        NRANKS,
        win_bytes(variant),
        KEY_LEN,
        VAL_LEN,
        net,
        4,
    );
    replay(&mut handles, sched)
}

/// Reference-model checks on one trace (the HashMap side of the oracle).
fn check_against_reference(
    sched: &[SchedOp],
    trace: &Trace,
) -> Result<(), String> {
    // replay the reference model: under cache semantics the DHT may
    // *miss* where the map has the key (eviction), but a hit must match
    // the map and must never precede the first write of that key
    let mut written: HashSet<u64> = HashSet::new();
    let mut ri = 0;
    for op in sched {
        let got = &trace.reads[ri];
        ri += 1;
        match got {
            Some(v) => {
                prop_assert!(
                    written.contains(&op.id),
                    "hit for id {} before any write",
                    op.id
                );
                prop_assert_eq!(
                    v,
                    &value_for(op.id, VAL_LEN),
                    "hit value for id {}",
                    op.id
                );
            }
            None => {
                // a miss is always legal (eviction); nothing to check
            }
        }
        if !op.read {
            // memoized-or-written: either way the key now holds its value
            written.insert(op.id);
        }
    }
    prop_assert_eq!(ri, trace.reads.len());

    // final contents: subset of the reference, values intact
    let reference: HashMap<Vec<u8>, Vec<u8>> = written
        .iter()
        .map(|&id| (key_for(id, KEY_LEN), value_for(id, VAL_LEN)))
        .collect();
    for (k, v) in &trace.entries {
        match reference.get(k) {
            Some(want) => prop_assert_eq!(v, want, "live value for key {k:?}"),
            None => {
                return Err(format!("phantom key {k:?} in final table"));
            }
        }
    }
    Ok(())
}

#[test]
fn all_variants_and_backends_agree_with_reference() {
    prop_check("differential-oracle", 12, |g| {
        let n = g.usize_in(40..160);
        let ids = g.u64_in(8..120);
        let read_pct = *g.pick(&[20u64, 50, 80]);
        let skewed = g.bool();
        let sched = g.schedule(n, NRANKS, ids, read_pct, skewed);

        let baseline = replay_shm(Variant::Coarse, &sched);
        check_against_reference(&sched, &baseline)?;

        for variant in Variant::ALL {
            let shm = replay_shm(variant, &sched);
            prop_assert_eq!(
                &shm,
                &baseline,
                "shm {variant:?} diverged from shm Coarse"
            );
            let des = replay_des(variant, &sched);
            prop_assert_eq!(
                &des,
                &baseline,
                "DES {variant:?} diverged from shm Coarse"
            );
        }
        Ok(())
    });
}

/// The tenancy refactor's oracle anchor (DESIGN.md §14): the
/// single-tenant default — explicit `tenant(0)` views under the `drop`
/// policy — must take the exact pre-tenant code path.  Identical trace,
/// identical serialized table, meta words included: every record still
/// carries the bare `Meta::OCCUPIED` word (no tenant/age stamping), so
/// the refactor is invisible until someone opts in.
#[test]
fn single_tenant_drop_default_is_byte_identical_to_pre_tenant_path() {
    let mut g = mpi_dht::util::prop::G::new(0x7E4A_0001);
    let sched = g.schedule(140, NRANKS, 60, 50, true);
    for variant in Variant::ALL {
        // plain cluster: the historical anonymous fill-then-drop table
        let mut plain =
            Dht::create(variant, NRANKS, win_bytes(variant), KEY_LEN, VAL_LEN);
        let base_trace = replay(&mut plain, &sched);
        let base_cp = DhtCheckpoint::capture(&plain);
        // tenant-0 views with the policy set explicitly to drop
        let handles =
            Dht::create(variant, NRANKS, win_bytes(variant), KEY_LEN, VAL_LEN);
        let mut views: Vec<_> = handles.iter().map(|h| h.tenant(0)).collect();
        for v in views.iter_mut() {
            v.set_evict(EvictPolicy::Drop);
        }
        let t = replay(&mut views, &sched);
        assert_eq!(t, base_trace, "{variant:?}: tenant(0)+drop trace diverged");
        let cp = DhtCheckpoint::capture(&views);
        assert_eq!(
            cp.to_bytes(),
            base_cp.to_bytes(),
            "{variant:?}: serialized tables must match byte for byte"
        );
        for (i, &m) in cp.entry_meta.iter().enumerate() {
            assert_eq!(
                m,
                Meta::OCCUPIED,
                "{variant:?}: entry {i} carries a stamped meta word"
            );
        }
    }
}

/// Pinned-seed reproducibility: the exact schedule CI replays must keep
/// producing byte-identical traces (the oracle is only trustworthy if a
/// reported seed reproduces).
#[test]
fn pinned_seed_trace_is_reproducible() {
    let mut g1 = mpi_dht::util::prop::G::new(0xD1FF_0AC1);
    let mut g2 = mpi_dht::util::prop::G::new(0xD1FF_0AC1);
    let s1 = g1.schedule(120, NRANKS, 48, 60, true);
    let s2 = g2.schedule(120, NRANKS, 48, 60, true);
    assert_eq!(s1, s2, "generator must be deterministic per seed");
    let a = replay_shm(Variant::Delegated, &s1);
    let b = replay_shm(Variant::Delegated, &s2);
    assert_eq!(a, b, "same seed, same trace");
    let c = replay_des(Variant::Delegated, &s1);
    assert_eq!(a.reads, c.reads, "backends agree on the pinned schedule");
    assert_eq!(a.entries, c.entries);
}
