//! Pipelined batch-operation layer, end to end (DESIGN.md §3):
//!
//! * `read_batch`/`write_batch` must produce the same outcomes as
//!   sequential `read`/`write` loops for all three variants;
//! * under real concurrency the batch API obeys the same contract as the
//!   blocking API (lock-free may miss, never returns a foreign value);
//! * on the DES backend, pipelining must *hide latency in simulated
//!   time*, and depth >= 16 must beat depth 1 on read throughput for the
//!   lock-free variant (the ablation's acceptance bar).

use std::collections::HashMap;

use mpi_dht::bench::keys::{key_for, value_for};
use mpi_dht::bench::{run_kv, Dist, KvCfg, Mode};
use mpi_dht::dht::{Dht, DhtOutcome, Variant};
use mpi_dht::net::NetConfig;

/// Batch results agree with a sequential model run.  For the locking
/// variants the agreement is exact (their locks serialize every bucket
/// access, so a single-threaded pipelined epoch is schedule-independent);
/// the lock-free variant is checked below under its own contract.
#[test]
fn batch_equals_sequential_loops_locking_variants() {
    for variant in [Variant::Coarse, Variant::Fine] {
        let mut seq = Dht::create_poet(variant, 4, 1 << 20);
        let mut bat = Dht::create_poet(variant, 4, 1 << 20);
        let mut model: HashMap<Vec<u8>, Vec<u8>> = HashMap::new();

        // three rounds of writes over the same ids so updates happen
        // (ids are distinct within each round: a batch with duplicate
        // keys races itself by design, like concurrent ranks would)
        for round in 0..3u64 {
            let keys: Vec<Vec<u8>> =
                (0..150u64).map(|i| key_for(i, 80)).collect();
            let vals: Vec<Vec<u8>> = (0..150u64)
                .map(|i| value_for(round * 1000 + i, 104))
                .collect();
            let mut seq_out = Vec::new();
            for (k, v) in keys.iter().zip(vals.iter()) {
                seq_out.push(seq[(round % 4) as usize].write(k, v));
                model.insert(k.clone(), v.clone());
            }
            let bat_out = bat[(round % 4) as usize].write_batch(&keys, &vals);
            assert_eq!(seq_out, bat_out, "{variant:?} round {round}");
        }

        // read everything back both ways
        let keys: Vec<Vec<u8>> = (0..150u64).map(|i| key_for(i, 80)).collect();
        let mut seq_out = Vec::new();
        for k in &keys {
            seq_out.push(seq[3].read(k));
        }
        let bat_out = bat[3].read_batch(&keys);
        assert_eq!(seq_out, bat_out, "{variant:?} reads");
        // and both agree with the model wherever they hit
        for (k, got) in keys.iter().zip(bat_out.iter()) {
            if let Some(v) = got {
                assert_eq!(v, &model[k], "{variant:?} stale value");
            }
        }
    }
}

/// Lock-free batches obey the paper's contract: hits always return the
/// key's own (latest-round) value; misses are possible only through
/// races/evictions and must stay rare at this load factor.
#[test]
fn batch_lockfree_reads_own_values() {
    let mut h = Dht::create_poet(Variant::LockFree, 4, 1 << 20);
    let keys: Vec<Vec<u8>> = (0..150u64).map(|i| key_for(i, 80)).collect();
    for round in 0..3u64 {
        let vals: Vec<Vec<u8>> = (0..150u64)
            .map(|i| value_for(round * 1000 + i, 104))
            .collect();
        h[(round % 4) as usize].write_batch(&keys, &vals);
    }
    let last: Vec<Vec<u8>> = (0..150u64)
        .map(|i| value_for(2 * 1000 + i, 104))
        .collect();
    let got = h[3].read_batch(&keys);
    let mut hits = 0;
    for (v, g) in last.iter().zip(got.iter()) {
        if let Some(gv) = g {
            assert_eq!(gv, v, "foreign or stale value");
            hits += 1;
        }
    }
    assert!(hits >= 140, "only {hits}/150 hits");
}

/// The existing concurrent-corruption harness, driven through the batch
/// API: values are derived from keys, so any foreign value is detected.
/// Lock-free may miss (torn write) but must never return a wrong value.
#[test]
fn concurrent_batches_no_corruption() {
    for variant in Variant::ALL {
        let handles = Dht::create_poet(variant, 4, 1 << 20);
        let mut threads = Vec::new();
        for (t, mut h) in handles.into_iter().enumerate() {
            threads.push(std::thread::spawn(move || {
                let mut wrong = 0u64;
                for round in 0..30u64 {
                    let ids: Vec<u64> = (0..32u64)
                        .map(|i| (round * 13 + t as u64 * 7 + i) % 96)
                        .collect();
                    let keys: Vec<Vec<u8>> =
                        ids.iter().map(|&id| key_for(id, 80)).collect();
                    if round % 3 == 0 {
                        let vals: Vec<Vec<u8>> = ids
                            .iter()
                            .map(|&id| value_for(id, 104))
                            .collect();
                        h.write_batch(&keys, &vals);
                    } else {
                        for (id, got) in
                            ids.iter().zip(h.read_batch(&keys))
                        {
                            if let Some(v) = got {
                                if v != value_for(*id, 104) {
                                    wrong += 1;
                                }
                            }
                        }
                    }
                }
                wrong
            }));
        }
        let wrong: u64 =
            threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(wrong, 0, "{variant:?} returned foreign values");
    }
}

/// Mixing blocking and batched calls on the same cluster is sound.
#[test]
fn batch_and_blocking_interoperate() {
    let mut h = Dht::create_poet(Variant::Fine, 2, 256 * 1024);
    let keys: Vec<Vec<u8>> = (0..20u64).map(|i| key_for(i, 80)).collect();
    let vals: Vec<Vec<u8>> = (0..20u64).map(|i| value_for(i, 104)).collect();
    h[0].write_batch(&keys, &vals);
    // blocking single-op reads see batched writes
    for (k, v) in keys.iter().zip(vals.iter()) {
        assert_eq!(h[1].read(k), Some(v.clone()));
    }
    // blocking write, batched read
    let k = key_for(777, 80);
    let v = value_for(778, 104);
    assert_eq!(h[1].write(&k, &v), DhtOutcome::WriteFresh);
    assert_eq!(h[0].read_batch(&[k]), vec![Some(v)]);
}

/// The DES ablation bar: lock-free simulated read throughput at depth 16
/// strictly above depth 1, for uniform and zipfian keys.
#[test]
fn sim_pipeline_depth_improves_read_throughput() {
    for dist in [Dist::Uniform, Dist::Zipfian] {
        let mut base = KvCfg::new(48, 300, dist, Mode::WriteThenRead);
        base.seed = 7;
        let d1 = run_kv(Variant::LockFree, NetConfig::pik_ndr(), base.clone());
        let mut piped = base;
        piped.pipeline = 16;
        let d16 = run_kv(Variant::LockFree, NetConfig::pik_ndr(), piped);
        assert!(
            d16.read_mops > d1.read_mops,
            "{dist:?}: depth16 {} <= depth1 {}",
            d16.read_mops,
            d1.read_mops
        );
        // both configurations execute the full workload
        assert_eq!(d1.stats.reads, d16.stats.reads);
        assert_eq!(d1.stats.writes, d16.stats.writes);
    }
}

/// Depth sensitivity is monotone-ish for lock-free reads: 16 also beats 4
/// beats 1 on this uncontended uniform workload.
#[test]
fn sim_pipeline_depth_ladder() {
    let mut mops = Vec::new();
    for depth in [1u32, 4, 16] {
        let mut cfg = KvCfg::new(32, 250, Dist::Uniform, Mode::WriteThenRead);
        cfg.pipeline = depth;
        let res = run_kv(Variant::LockFree, NetConfig::pik_ndr(), cfg);
        mops.push(res.read_mops);
    }
    assert!(mops[1] > mops[0], "depth 4 {} <= depth 1 {}", mops[1], mops[0]);
    assert!(mops[2] > mops[1], "depth 16 {} <= depth 4 {}", mops[2], mops[1]);
}
