//! CLI smoke tests: every subcommand runs and prints the expected tables.

use std::process::Command;

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_mpi-dht"))
        .args(args)
        .output()
        .expect("spawn mpi-dht");
    let text = String::from_utf8_lossy(&out.stdout).to_string()
        + &String::from_utf8_lossy(&out.stderr);
    (out.status.success(), text)
}

#[test]
fn help_lists_commands() {
    let (ok, text) = run(&["help"]);
    assert!(ok);
    for cmd in ["bench-kv", "bench-daos", "poet-des", "poet", "info"] {
        assert!(text.contains(cmd), "help misses {cmd}");
    }
}

#[test]
fn info_runs() {
    let (ok, text) = run(&["info"]);
    assert!(ok, "{text}");
    assert!(text.contains("mpi-dht"));
}

#[test]
fn bench_kv_prints_table() {
    let (ok, text) = run(&[
        "bench-kv", "--variant", "lockfree", "--dist", "uniform",
        "--ranks", "16", "--ops", "200",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("read Mops"), "{text}");
    assert!(text.contains("| 16 |") || text.contains("|    16 |"), "{text}");
}

#[test]
fn bench_kv_rejects_bad_variant() {
    let (ok, text) = run(&["bench-kv", "--variant", "bogus"]);
    assert!(!ok);
    assert!(text.contains("unknown variant"), "{text}");
    // the error must teach the accepted spellings, not just reject
    for name in ["coarse", "fine", "lockfree", "lock-free", "delegated"] {
        assert!(text.contains(name), "accepted name {name} missing: {text}");
    }
}

#[test]
fn help_lists_delegated_variant() {
    let (ok, text) = run(&["help"]);
    assert!(ok);
    assert!(text.contains("delegated"), "help misses delegated: {text}");
    assert!(text.contains("hotkey"), "help misses hotkey dist: {text}");
}

#[test]
fn bench_kv_runs_delegated_hotkey() {
    let (ok, text) = run(&[
        "bench-kv", "--variant", "delegated", "--dist", "hotkey",
        "--ranks", "16", "--ops", "200",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("variant=delegated"), "{text}");
    assert!(text.contains("read Mops"), "{text}");
}

#[test]
fn poet_des_runs_delegated() {
    let (ok, text) = run(&[
        "poet-des", "--ranks", "8", "--ny", "8", "--nx", "16", "--steps",
        "5", "--variant", "delegated",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("variant=delegated"), "{text}");
    assert!(text.contains("hit rate"), "{text}");
}

#[test]
fn poet_resize_flags_print_recovery_line() {
    let (ok, text) = run(&[
        "poet", "--engine", "native", "--ny", "8", "--nx", "16", "--steps",
        "12", "--workers", "1", "--variant", "lockfree", "--win-bytes",
        "8192", "--resize-at-iter", "6", "--resize-factor", "16",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("resize at step 6"), "{text}");
    assert!(text.contains("migrated"), "{text}");
}

#[test]
fn bench_daos_prints_table() {
    let (ok, text) =
        run(&["bench-daos", "--clients", "12", "--ops", "300"]);
    assert!(ok, "{text}");
    assert!(text.contains("daos read Mops"), "{text}");
}

#[test]
fn poet_des_prints_table() {
    let (ok, text) = run(&[
        "poet-des", "--ranks", "8", "--ny", "8", "--nx", "16", "--steps",
        "5", "--variant", "lockfree",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("runtime s"), "{text}");
    assert!(text.contains("hit rate"), "{text}");
}

#[test]
fn poet_des_chaos_flags_run() {
    let (ok, text) = run(&[
        "poet-des", "--ranks", "4", "--ny", "8", "--nx", "8", "--steps",
        "4", "--variant", "lockfree", "--replicas", "2", "--kill-rank",
        "1", "--kill-rank-at", "0.001",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("failovers"), "{text}");
    assert!(text.contains("repl writes"), "{text}");
}

#[test]
fn poet_des_rejects_out_of_range_kill_rank() {
    let (ok, text) = run(&[
        "poet-des", "--ranks", "4", "--ny", "8", "--nx", "8", "--steps",
        "2", "--variant", "lockfree", "--kill-rank", "9",
        "--kill-rank-at", "1",
    ]);
    assert!(!ok);
    assert!(text.contains("out of range"), "{text}");
}

#[test]
fn poet_native_runs() {
    let (ok, text) = run(&[
        "poet", "--engine", "native", "--ny", "8", "--nx", "16", "--steps",
        "5", "--workers", "1", "--variant", "lockfree",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("reference"), "{text}");
    assert!(text.contains("lock-free"), "{text}");
    assert!(text.contains("speedup"), "{text}");
}

#[test]
fn unknown_command_fails_gracefully() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"), "{text}");
}
