//! Cross-module DHT integration: the three variants must implement the
//! same key-value semantics on the threaded shm backend AND inside the
//! DES cluster, under both serialized and concurrent schedules.

use std::collections::HashMap;

use mpi_dht::bench::keys::{key_for, value_for};
use mpi_dht::dht::{Dht, DhtOutcome, Variant};

/// All variants agree with a model HashMap under a serialized schedule of
/// interleaved writes/updates/reads.
#[test]
fn serialized_model_equivalence() {
    for variant in Variant::ALL {
        let mut h = Dht::create_poet(variant, 8, 1 << 20);
        let mut model: HashMap<Vec<u8>, Vec<u8>> = HashMap::new();
        let mut evicted = 0u64;
        for i in 0..2_000u64 {
            let id = i % 700; // updates guaranteed
            let key = key_for(id, 80);
            let val = value_for(id * 31 + i, 104);
            let rank = (i % 8) as usize;
            match h[rank].write(&key, &val) {
                DhtOutcome::WriteEvict => evicted += 1,
                _ => {}
            }
            model.insert(key, val);
        }
        let mut misses = 0u64;
        for (key, val) in &model {
            match h[3].read(key) {
                Some(v) => assert_eq!(&v, val, "{variant:?} stale value"),
                None => misses += 1,
            }
        }
        // misses can only come from cache evictions
        assert!(
            misses <= evicted,
            "{variant:?}: {misses} misses but only {evicted} evictions"
        );
        // at 700 keys in 8 x 5242-bucket windows evictions are rare
        assert!(misses < 20, "{variant:?}: excessive misses {misses}");
    }
}

/// Heavy concurrent mixed workload: no variant may ever return a value
/// that does not belong to the requested key (values are derived from
/// keys, so mismatches are detectable).
#[test]
fn concurrent_consistency_stress() {
    for variant in Variant::ALL {
        let handles = Dht::create_poet(variant, 4, 1 << 20);
        let mut threads = Vec::new();
        for (t, mut h) in handles.into_iter().enumerate() {
            threads.push(std::thread::spawn(move || {
                let mut wrong = 0u64;
                let mut ops = 0u64;
                for round in 0..400u64 {
                    let id = (round * 7 + t as u64) % 64;
                    let key = key_for(id, 80);
                    if round % 3 == 0 {
                        h.write(&key, &value_for(id, 104));
                    } else if let Some(v) = h.read(&key) {
                        if v != value_for(id, 104) {
                            wrong += 1;
                        }
                    }
                    ops += 1;
                }
                (wrong, ops)
            }));
        }
        let mut wrong = 0;
        for th in threads {
            let (w, _) = th.join().unwrap();
            wrong += w;
        }
        assert_eq!(wrong, 0, "{variant:?} returned foreign values");
    }
}

/// The same benchmark workload replayed on the DES backend returns the
/// same logical results (hits, misses) as the shm backend: protocol state
/// machines are backend-independent.
#[test]
fn backend_equivalence_write_then_read() {
    use mpi_dht::bench::{run_kv, Dist, KvCfg, Mode};
    use mpi_dht::net::NetConfig;

    for variant in Variant::ALL {
        // DES run
        let mut cfg = KvCfg::new(4, 300, Dist::Uniform, Mode::WriteThenRead);
        cfg.seed = 99;
        let des = run_kv(variant, NetConfig::pik_ndr(), cfg.clone());

        // shm replay of the same deterministic id stream
        let mut h = Dht::create_poet(
            variant,
            4,
            cfg.win_bytes_effective(
                mpi_dht::dht::BucketLayout::new(variant, 80, 104).size(),
            ),
        );
        let mut hits = 0u64;
        for rank in 0..4u64 {
            let mut rng =
                mpi_dht::util::rng::Rng::new(cfg.seed ^ (rank << 20));
            for _ in 0..cfg.ops_per_rank {
                let id = rng.next_u64();
                h[rank as usize].write(&key_for(id, 80), &value_for(id, 104));
            }
        }
        for rank in 0..4u64 {
            let mut rng =
                mpi_dht::util::rng::Rng::new(cfg.seed ^ (rank << 20));
            for _ in 0..cfg.ops_per_rank {
                let id = rng.next_u64();
                if h[rank as usize].read(&key_for(id, 80)).is_some() {
                    hits += 1;
                }
            }
        }
        assert_eq!(
            des.stats.read_hits, hits,
            "{variant:?}: DES {} vs shm {hits} hits",
            des.stats.read_hits
        );
    }
}

/// Key/value sizes other than the POET defaults work end to end
/// (the paper's future work mentions different value sizes).
#[test]
fn alternative_record_geometries() {
    for (klen, vlen) in [(16, 32), (8, 8), (80, 1024), (33, 7)] {
        let mut h = Dht::create(Variant::LockFree, 2, 1 << 20, klen, vlen);
        let key: Vec<u8> = (0..klen as u32).map(|i| i as u8).collect();
        let val: Vec<u8> = (0..vlen as u32).map(|i| (i * 3) as u8).collect();
        h[0].write(&key, &val);
        assert_eq!(h[1].read(&key), Some(val), "geometry {klen}/{vlen}");
    }
}

/// Window too small for even one bucket must panic loudly, not corrupt.
#[test]
#[should_panic(expected = "window smaller than one bucket")]
fn tiny_window_rejected() {
    let _ = Dht::create_poet(Variant::LockFree, 1, 64);
}

// ---------------------------------------------------------------------------
// Checkpoint/restore with resizing — the paper's §6 future-work feature.
// ---------------------------------------------------------------------------

use mpi_dht::dht::DhtCheckpoint;

#[test]
fn checkpoint_restore_roundtrip_resized() {
    // write into a 4-rank table, checkpoint, restore into 7 ranks with a
    // different window size AND a different variant; every entry survives
    let mut src_handles = Dht::create_poet(Variant::LockFree, 4, 1 << 20);
    for i in 0..500u64 {
        src_handles[(i % 4) as usize]
            .write(&key_for(i, 80), &value_for(i * 13, 104));
    }
    let ckpt = DhtCheckpoint::capture(&src_handles);
    assert!(ckpt.entries.len() >= 495, "{} captured", ckpt.entries.len());

    // serialize + parse round trip
    let bytes = ckpt.to_bytes();
    let parsed = DhtCheckpoint::from_bytes(&bytes).expect("parse");
    assert_eq!(parsed.entries.len(), ckpt.entries.len());
    assert_eq!(parsed.key_len, 80);

    // restore resized (more ranks, smaller windows) and cross-variant
    let mut restored = parsed.restore(Variant::Fine, 7, 512 * 1024);
    let mut hits = 0;
    for i in 0..500u64 {
        if restored[(i % 7) as usize].read(&key_for(i, 80))
            == Some(value_for(i * 13, 104))
        {
            hits += 1;
        }
    }
    assert!(hits >= 495, "{hits}/500 after restore");
}

#[test]
fn checkpoint_skips_invalid_buckets() {
    let mut handles = Dht::create_poet(Variant::LockFree, 2, 1 << 20);
    for i in 0..50u64 {
        handles[0].write(&key_for(i, 80), &value_for(i, 104));
    }
    let before = DhtCheckpoint::capture(&handles).entries.len();
    assert!(before >= 49);
    // shrink to a tiny table: evictions happen, entries never duplicate
    let restored = DhtCheckpoint::capture(&handles).restore(
        Variant::LockFree,
        1,
        40 * 200, // 40 buckets
    );
    let total_writes: u64 = restored.iter().map(|h| h.stats().writes).sum();
    assert_eq!(total_writes, 0, "restore stats must be cleared");
}

#[test]
fn checkpoint_from_bytes_rejects_garbage() {
    assert!(DhtCheckpoint::from_bytes(b"").is_none());
    assert!(DhtCheckpoint::from_bytes(b"DHTCKPT1").is_none());
    let good = {
        let mut h = Dht::create_poet(Variant::LockFree, 1, 1 << 20);
        h[0].write(&key_for(1, 80), &value_for(1, 104));
        DhtCheckpoint::capture(&h).to_bytes()
    };
    // truncated payload
    let mut truncated = good.clone();
    truncated.pop();
    assert!(DhtCheckpoint::from_bytes(&truncated).is_none());
    // trailing garbage (length mismatch the other way)
    let mut padded = good.clone();
    padded.push(0);
    assert!(DhtCheckpoint::from_bytes(&padded).is_none());
    // corrupted magic
    let mut bad_magic = good.clone();
    bad_magic[0] ^= 0xFF;
    assert!(DhtCheckpoint::from_bytes(&bad_magic).is_none());
    // unknown variant byte
    let mut bad_variant = good.clone();
    bad_variant[8] = 9;
    assert!(DhtCheckpoint::from_bytes(&bad_variant).is_none());
    // zero-length record geometry must be rejected, not divide the world
    let mut bad_geom = good.clone();
    bad_geom[9..13].copy_from_slice(&0u32.to_le_bytes());
    assert!(DhtCheckpoint::from_bytes(&bad_geom).is_none());
    // an entry count crafted to wrap `25 + n * rec` must not pass the
    // length check (or blow up Vec::with_capacity)
    let mut overflow = Vec::new();
    overflow.extend_from_slice(b"DHTCKPT1");
    overflow.push(2); // lock-free
    overflow.extend_from_slice(&1u32.to_le_bytes()); // key_len = 1
    overflow.extend_from_slice(&7u32.to_le_bytes()); // val_len = 7
    overflow.extend_from_slice(&(1u64 << 61).to_le_bytes()); // n * 8 wraps
    assert_eq!(overflow.len(), 25);
    assert!(DhtCheckpoint::from_bytes(&overflow).is_none());
    // the untouched original still parses
    assert!(DhtCheckpoint::from_bytes(&good).is_some());
}

/// `to_bytes`/`from_bytes` round-trips exactly, for all three variants.
#[test]
fn checkpoint_bytes_roundtrip_all_variants() {
    for variant in Variant::ALL {
        let mut h = Dht::create_poet(variant, 3, 1 << 20);
        for i in 0..120u64 {
            h[(i % 3) as usize].write(&key_for(i, 80), &value_for(i * 7, 104));
        }
        let ckpt = DhtCheckpoint::capture(&h);
        assert!(ckpt.entries.len() >= 118, "{variant:?}");
        let parsed =
            DhtCheckpoint::from_bytes(&ckpt.to_bytes()).expect("parse");
        assert_eq!(parsed.variant, ckpt.variant, "{variant:?}");
        assert_eq!(parsed.key_len, ckpt.key_len);
        assert_eq!(parsed.val_len, ckpt.val_len);
        // entry multiset identical (order is deterministic: window scan)
        assert_eq!(parsed.entries, ckpt.entries, "{variant:?}");
    }
}

/// Shrinking restore (ranks 4 -> 2, much smaller windows): entries
/// re-route, evictions happen, and everything still readable is correct.
#[test]
fn checkpoint_restore_shrinking_geometry() {
    let mut src = Dht::create_poet(Variant::LockFree, 4, 1 << 20);
    for i in 0..400u64 {
        src[(i % 4) as usize].write(&key_for(i, 80), &value_for(i * 3, 104));
    }
    let ckpt = DhtCheckpoint::capture(&src);
    assert!(ckpt.entries.len() >= 395);

    // 2 ranks x 100 buckets: far too small for 400 entries -> evictions
    let bucket = mpi_dht::dht::BucketLayout::new(Variant::LockFree, 80, 104)
        .size();
    let mut small = ckpt.restore(Variant::LockFree, 2, 100 * bucket);
    let mut hits = 0u64;
    for i in 0..400u64 {
        if let Some(v) = small[(i % 2) as usize].read(&key_for(i, 80)) {
            assert_eq!(v, value_for(i * 3, 104), "wrong value after restore");
            hits += 1;
        }
    }
    // the shrunken table keeps only what fits, but never invents data
    assert!(hits > 0, "some entries must survive");
    assert!(
        (hits as usize) < ckpt.entries.len(),
        "a 200-bucket table cannot hold all {} entries",
        ckpt.entries.len()
    );
    // restore stats were cleared; only our probe reads are counted
    let reads: u64 = small.iter().map(|h| h.stats().reads).sum();
    assert_eq!(reads, 400);
}
