//! Elastic-resize integration (DESIGN.md §8): online capacity changes
//! with live migration, across variants and backends.

use mpi_dht::bench::keys::{key_for, value_for};
use mpi_dht::dht::{Dht, DhtCheckpoint, Variant};
use mpi_dht::net::{NetConfig, Network};

const KEY: usize = 16;
const VAL: usize = 32;

/// Every key readable before a grow stays readable during the migration
/// epoch (dual lookup) and after it closes, on every variant.
#[test]
fn grow_preserves_entries_all_variants() {
    for variant in Variant::ALL {
        let bucket =
            mpi_dht::dht::BucketLayout::new(variant, KEY, VAL).size();
        let mut h = Dht::create(variant, 4, 256 * bucket, KEY, VAL);
        let mut present = Vec::new();
        for i in 0..400u64 {
            h[(i % 4) as usize].write(&key_for(i, KEY), &value_for(i, VAL));
        }
        for i in 0..400u64 {
            if h[1].read(&key_for(i, KEY)) == Some(value_for(i, VAL)) {
                present.push(i);
            }
        }
        assert!(present.len() > 300, "{variant:?}: table mostly loaded");

        let old = h[0].buckets_per_rank();
        h[0].resize(old * 4).expect("resize");
        assert!(h[2].migrating(), "{variant:?}: epoch visible everywhere");
        assert_eq!(h[2].epoch() % 2, 1);
        // mid-migration: present keys stay readable through the dual
        // lookup; values are never foreign.  (Lock-free tolerates rare
        // candidate-race evictions — the §4.2 last-write-wins contract.)
        let survivors = |h: &mut Dht, tag: &str| -> usize {
            let mut n = 0;
            for &i in &present {
                if let Some(v) = h.read(&key_for(i, KEY)) {
                    assert_eq!(v, value_for(i, VAL), "{tag} key {i}");
                    n += 1;
                }
            }
            n
        };
        let mid = survivors(&mut h[2], "mid-migration");
        assert!(
            mid + 2 >= present.len(),
            "{variant:?}: only {mid}/{} readable mid-migration",
            present.len()
        );
        // drive the epoch closed from a single handle (work stealing)
        h[3].drain_migration();
        for hh in h.iter_mut() {
            assert!(!hh.migrating(), "{variant:?}: epoch must be closed");
            assert_eq!(hh.buckets_per_rank(), old * 4);
        }
        let after = survivors(&mut h[0], "post-migration");
        assert!(
            after + 2 >= present.len(),
            "{variant:?}: only {after}/{} survived migration",
            present.len()
        );
        // the locking variants are loss-free by construction (the CRC
        // variants — lock-free and delegated — tolerate rare races)
        if !variant.has_crc() {
            assert_eq!(after, present.len(), "{variant:?} lost entries");
        }
        // migration counters landed somewhere in the cluster
        let mut stats = mpi_dht::dht::DhtStats::default();
        for hh in h.iter() {
            stats.merge(hh.stats());
        }
        assert_eq!(stats.resizes, 1, "{variant:?}");
        assert!(
            stats.migrated as usize + 2 >= present.len(),
            "{variant:?}: migrated {} < present {}",
            stats.migrated,
            present.len()
        );
        assert!(stats.dual_reads > 0, "{variant:?}: dual lookups counted");
    }
}

/// Delegated × resize (DESIGN.md §12): mid-epoch dual reads ride one
/// mailbox round trip per table probed, while the migration traffic
/// itself stays on the control plane (raw CRC-guarded RMA) and never
/// inflates the mailbox counters.
#[test]
fn delegated_resize_dual_reads_ride_mailboxes() {
    let bucket =
        mpi_dht::dht::BucketLayout::new(Variant::Delegated, KEY, VAL).size();
    let mut h = Dht::create(Variant::Delegated, 4, 256 * bucket, KEY, VAL);
    for i in 0..300u64 {
        h[(i % 4) as usize].write(&key_for(i, KEY), &value_for(i, VAL));
    }
    // drain the load-phase counters so the mid-epoch window is isolated
    let mut loaded = mpi_dht::dht::DhtStats::default();
    for hh in h.iter_mut() {
        loaded.merge(&hh.take_stats());
    }
    assert_eq!(loaded.mailbox_ops, loaded.reads + loaded.writes);

    let old = h[0].buckets_per_rank();
    h[0].resize(old * 4).expect("resize");
    assert!(h[1].migrating());
    // mid-epoch: every present key stays readable through the dual
    // lookup and the values are the delegated shard's own
    let mut hits = 0u64;
    for i in 0..300u64 {
        if let Some(v) = h[2].read(&key_for(i, KEY)) {
            assert_eq!(v, value_for(i, VAL), "key {i}");
            hits += 1;
        }
    }
    assert!(hits > 250, "only {hits}/300 readable mid-migration");
    h[3].drain_migration();
    for hh in h.iter_mut() {
        assert!(!hh.migrating());
    }
    let mut mid = mpi_dht::dht::DhtStats::default();
    for hh in h.iter_mut() {
        mid.merge(&hh.take_stats());
    }
    // a dual read probes up to two tables: mailbox round trips must be
    // >= the reads that found their key in the *new* table and <= two
    // per read — and some reads genuinely went dual
    assert!(mid.dual_reads > 0, "dual lookups counted");
    assert!(mid.mailbox_ops >= mid.reads, "{} < {}", mid.mailbox_ops, mid.reads);
    assert!(
        mid.mailbox_ops <= 2 * mid.reads,
        "{} > 2x{} — migration traffic leaked into the mailbox counters",
        mid.mailbox_ops,
        mid.reads
    );
    // post-migration reads still work over the mailbox
    assert_eq!(h[0].read(&key_for(7, KEY)), Some(value_for(7, VAL)));
}

/// Writes during a migration epoch land in the new table and win over
/// the old copy; reads see them immediately, mid-epoch and after.
/// (Single-threaded schedule: the write completes before the migration
/// quantum that could race it, so "newer wins" is deterministic here —
/// under real concurrency the lock-free variant's same-key races are
/// last-write-wins, see `dht::migrate` invariant 3.)
#[test]
fn writes_during_migration_supersede_old_entries() {
    let mut h = Dht::create(Variant::LockFree, 2, 64 * 1024, KEY, VAL);
    let stale = key_for(1, KEY);
    let fresh = key_for(2, KEY);
    h[0].write(&stale, &value_for(10, VAL));
    h[0].write(&fresh, &value_for(20, VAL));
    let old = h[0].buckets_per_rank();
    h[0].resize(old * 2).expect("resize");
    // update one key mid-epoch: the write goes to the new table only
    assert!(h[1].migrating());
    h[1].write(&fresh, &value_for(99, VAL));
    assert_eq!(h[0].read(&fresh), Some(value_for(99, VAL)));
    assert_eq!(h[0].read(&stale), Some(value_for(10, VAL)));
    h[0].drain_migration();
    // after the epoch: the mid-epoch update won, nothing resurrected
    assert_eq!(h[1].read(&fresh), Some(value_for(99, VAL)));
    assert_eq!(h[1].read(&stale), Some(value_for(10, VAL)));
    // both occupied old buckets were processed: `stale` was copied, and
    // `fresh` was either copied-then-updated (if its bucket migrated
    // before our write) or skipped as superseded — never lost
    let copied: u64 = h.iter().map(|x| x.stats().migrated).sum();
    let skipped: u64 = h.iter().map(|x| x.stats().migrate_skipped).sum();
    assert!(
        copied + skipped >= 2,
        "copied {copied} + skipped {skipped}"
    );
}

/// A second resize during an open epoch is rejected with a clear error;
/// after the epoch closes it succeeds.
#[test]
fn concurrent_resize_rejected() {
    let mut h = Dht::create(Variant::Fine, 2, 32 * 1024, KEY, VAL);
    let old = h[0].buckets_per_rank();
    h[0].resize(old * 2).expect("first resize");
    let err = h[1].resize(old * 8).unwrap_err();
    assert!(
        format!("{err}").contains("progress"),
        "unexpected error: {err}"
    );
    assert_eq!(format!("{}", h[0].resize(0).unwrap_err()), "resize: bucket count must be > 0");
    h[0].drain_migration();
    h[1].resize(old * 8).expect("resize after close");
    h[1].drain_migration();
    assert_eq!(h[0].buckets_per_rank(), old * 8);
}

/// The same elastic protocol runs inside the DES cluster, in simulated
/// time, with the pipelined batch front-end.
#[test]
fn sim_backend_resize_roundtrip() {
    let net = Network::new(NetConfig::pik_ndr(), 4);
    let mut h =
        Dht::create_sim(Variant::LockFree, 4, 64 * 1024, KEY, VAL, net, 8);
    let keys: Vec<Vec<u8>> = (0..64u64).map(|i| key_for(i, KEY)).collect();
    let vals: Vec<Vec<u8>> =
        (0..64u64).map(|i| value_for(i * 7, VAL)).collect();
    h[0].write_batch(&keys, &vals);
    let t_loaded = h[0].sim_time();
    let old = h[0].buckets_per_rank();
    h[0].resize(old * 2).expect("resize");
    // dual lookups from another rank, mid-epoch, in simulated time
    // (hits verified; lock-free tolerates rare candidate-race drops)
    let count_hits = |got: &[Option<Vec<u8>>]| -> usize {
        let mut hits = 0;
        for (g, v) in got.iter().zip(vals.iter()) {
            if let Some(gv) = g {
                assert_eq!(gv, v, "foreign value in sim read");
                hits += 1;
            }
        }
        hits
    };
    let got = h[3].read_batch(&keys);
    assert!(count_hits(&got) >= 62, "mid-migration sim reads");
    assert!(h[3].sim_time() > t_loaded, "sim time advanced");
    h[2].drain_migration();
    assert!(!h[1].migrating());
    let got = h[1].read_batch(&keys);
    assert!(count_hits(&got) >= 62, "post-migration sim reads");
    let migrated: u64 = h.iter().map(|x| x.stats().migrated).sum();
    assert!(
        (62..=64).contains(&migrated),
        "every occupied bucket migrated exactly once: {migrated}"
    );
}

/// Shrinking keeps cache semantics: surviving entries are always correct,
/// overflow is dropped (never corrupted), and the drop is counted.
#[test]
fn shrink_drops_overflow_never_corrupts() {
    let mut h = Dht::create(Variant::LockFree, 1, 128 * 1024, KEY, VAL);
    let n = 600u64;
    for i in 0..n {
        h[0].write(&key_for(i, KEY), &value_for(i * 11, VAL));
    }
    h[0].resize(40).expect("shrink");
    h[0].drain_migration();
    assert_eq!(h[0].buckets_per_rank(), 40);
    let mut hits = 0u64;
    for i in 0..n {
        if let Some(v) = h[0].read(&key_for(i, KEY)) {
            assert_eq!(v, value_for(i * 11, VAL), "stale/foreign value");
            hits += 1;
        }
    }
    assert!(hits > 0, "some entries survive");
    assert!(hits <= 40, "a 40-bucket table holds at most 40 entries");
    let s = h[0].stats();
    assert!(s.migrate_dropped > 0, "overflow drops are counted");
    assert!(s.migrated <= 40);
}

/// k-way replication composes with the elastic resize (DESIGN.md §9):
/// placement is rescale-stable, so a mid-epoch grow keeps every replica
/// readable and degraded-read failover works across the migration epoch.
#[test]
fn replicated_cluster_resizes_without_losing_failover() {
    let mut h = Dht::create(Variant::LockFree, 4, 64 * 1024, KEY, VAL);
    for hh in h.iter_mut() {
        hh.set_replicas(2);
    }
    let keys: Vec<Vec<u8>> = (0..120u64).map(|i| key_for(i, KEY)).collect();
    let vals: Vec<Vec<u8>> =
        (0..120u64).map(|i| value_for(i * 5, VAL)).collect();
    h[0].write_batch(&keys, &vals);
    let old = h[0].buckets_per_rank();
    h[0].resize(old * 2).expect("resize");
    assert!(h[1].migrating());
    assert_eq!(h[1].replicas(), 2, "replication survives the epoch flip");
    // mid-epoch with a masked rank: dual lookup + failover compose
    h[2].set_rank_failed(1, true);
    let got = h[2].read_batch(&keys);
    let hits = got
        .iter()
        .zip(vals.iter())
        .filter(|(g, v)| g.as_ref() == Some(*v))
        .count();
    assert!(hits >= 118, "mid-epoch masked hits {hits}/120");
    assert!(h[2].stats().failover_reads > 0, "failover engaged mid-epoch");
    h[2].set_rank_failed(1, false);
    h[3].drain_migration();
    for hh in h.iter_mut() {
        assert!(!hh.migrating());
        assert_eq!(hh.replicas(), 2, "replication survives epoch close");
        assert_eq!(hh.buckets_per_rank(), old * 2);
    }
    let got = h[0].read_batch(&keys);
    let hits = got
        .iter()
        .zip(vals.iter())
        .filter(|(g, v)| g.as_ref() == Some(*v))
        .count();
    assert!(hits >= 118, "post-epoch hits {hits}/120");
}

/// Online repair composes with the elastic resize (DESIGN.md §11 x §8):
/// repair defers while a migration epoch is open (records are mid-flight
/// between tables), resumes once the epoch closes, and the two
/// subsystems together lose nothing — every surviving key keeps k
/// distinct live copies and reads stay correct throughout.
#[test]
fn repair_defers_during_resize_and_completes_after() {
    let mut h = Dht::create(Variant::LockFree, 4, 64 * 1024, KEY, VAL);
    for hh in h.iter_mut() {
        hh.set_replicas(2);
        hh.set_repair(true);
    }
    let keys: Vec<Vec<u8>> = (0..120u64).map(|i| key_for(i, KEY)).collect();
    let vals: Vec<Vec<u8>> =
        (0..120u64).map(|i| value_for(i * 5, VAL)).collect();
    h[0].write_batch(&keys, &vals);
    let old = h[0].buckets_per_rank();
    h[0].resize(old * 2).expect("resize");
    assert!(h[1].migrating());
    // rank 1 dies mid-epoch: repair must NOT touch the moving tables
    h[2].set_rank_failed(1, true);
    h[2].drain_repair();
    assert_eq!(
        h[2].stats().repaired,
        0,
        "repair defers while the epoch is open"
    );
    // reads still work mid-epoch through dual lookup + failover
    let got = h[2].read_batch(&keys);
    let hits = got
        .iter()
        .zip(vals.iter())
        .filter(|(g, v)| g.as_ref() == Some(*v))
        .count();
    assert!(hits >= 118, "mid-epoch masked hits {hits}/120");
    // close the epoch, then drain the deferred repair pass everywhere
    h[3].drain_migration();
    let mut repaired = 0u64;
    for r in [0usize, 2, 3] {
        h[r].drain_repair();
        assert!(!h[r].repairing(), "pass must complete");
        repaired += h[r].stats().repaired;
    }
    assert!(repaired > 0, "deferred repair ran after the epoch closed");
    // after repair every key is served without touching the dead rank
    let got = h[3].read_batch(&keys);
    let hits = got
        .iter()
        .zip(vals.iter())
        .filter(|(g, v)| g.as_ref() == Some(*v))
        .count();
    assert!(hits >= 118, "post-repair hits {hits}/120");
    assert_eq!(
        h[3].stats().mismatches,
        0,
        "no corruption across resize x repair"
    );
}

/// Back-to-back epochs: grow, then grow again — each resize allocates a
/// fresh window segment and the chain of epochs stays consistent.
#[test]
fn repeated_resizes_chain_epochs() {
    let mut h = Dht::create(Variant::LockFree, 2, 32 * 1024, KEY, VAL);
    for i in 0..50u64 {
        h[(i % 2) as usize].write(&key_for(i, KEY), &value_for(i, VAL));
    }
    let b0 = h[0].buckets_per_rank();
    for round in 1..=3u64 {
        h[0].resize(b0 * (1 << round)).expect("grow");
        h[1].drain_migration();
        // h[0] must first observe the close published by h[1]'s drain
        assert!(!h[0].migrating());
        assert_eq!(h[0].epoch(), round * 2, "two epoch steps per resize");
        let mut hits = 0;
        for i in 0..50u64 {
            if let Some(v) = h[1].read(&key_for(i, KEY)) {
                assert_eq!(v, value_for(i, VAL), "round {round}, key {i}");
                hits += 1;
            }
        }
        // lock-free tolerates rare candidate-race drops per round
        assert!(hits >= 48, "round {round}: only {hits}/50 survived");
    }
    assert_eq!(h[0].buckets_per_rank(), b0 * 8);
}

/// A checkpoint captured mid-migration sees both tables (union of
/// entries, new table wins).
#[test]
fn checkpoint_capture_during_migration_sees_both_tables() {
    let mut h = Dht::create(Variant::LockFree, 2, 64 * 1024, KEY, VAL);
    for i in 0..100u64 {
        h[(i % 2) as usize].write(&key_for(i, KEY), &value_for(i, VAL));
    }
    let old = h[0].buckets_per_rank();
    h[0].resize(old * 2).expect("resize");
    // mid-epoch write supersedes one old entry
    h[1].write(&key_for(5, KEY), &value_for(555, VAL));
    let ckpt = DhtCheckpoint::capture(&h);
    assert!(ckpt.entries.len() >= 99, "{} captured", ckpt.entries.len());
    let map: std::collections::HashMap<_, _> =
        ckpt.entries.iter().cloned().collect();
    assert_eq!(map.get(&key_for(5, KEY)), Some(&value_for(555, VAL)));
    assert_eq!(map.get(&key_for(6, KEY)), Some(&value_for(6, VAL)));
    // v2 geometry reflects the *new* table mid-migration
    assert_eq!(ckpt.buckets_per_rank, Some(old * 2));
    assert_eq!(ckpt.nranks, Some(2));
}

/// Checkpoint format v3 round-trips its geometry; legacy v1/v2 bytes
/// still load (v1 with no geometry, both with unstamped metas);
/// `restore_strict` rejects a too-small target with a clear error and
/// accepts an adequate one.
#[test]
fn checkpoint_v3_geometry_and_legacy_compat() {
    use mpi_dht::dht::bucket::Meta;
    let mut h = Dht::create(Variant::LockFree, 2, 64 * 1024, KEY, VAL);
    for i in 0..50u64 {
        h[0].write(&key_for(i, KEY), &value_for(i, VAL));
    }
    let ckpt = DhtCheckpoint::capture(&h);
    let per_rank = h[0].buckets_per_rank();
    assert_eq!(ckpt.buckets_per_rank, Some(per_rank));
    assert_eq!(ckpt.nranks, Some(2));
    let bytes = ckpt.to_bytes();
    assert_eq!(&bytes[..8], b"DHTCKPT3");
    let parsed = DhtCheckpoint::from_bytes(&bytes).expect("v3 parse");
    assert_eq!(parsed.buckets_per_rank, Some(per_rank));
    assert_eq!(parsed.nranks, Some(2));
    assert_eq!(parsed.entries, ckpt.entries);
    assert_eq!(parsed.entry_meta, ckpt.entry_meta);

    // hand-built v2 payload (a pre-v3 build's serialization): geometry
    // head, meta-less records — loads with unstamped tenant-0 metas
    let mut v2 = Vec::new();
    v2.extend_from_slice(b"DHTCKPT2");
    v2.push(2); // lock-free
    v2.extend_from_slice(&(KEY as u32).to_le_bytes());
    v2.extend_from_slice(&(VAL as u32).to_le_bytes());
    v2.extend_from_slice(&per_rank.to_le_bytes());
    v2.extend_from_slice(&2u32.to_le_bytes());
    v2.extend_from_slice(&1u64.to_le_bytes());
    v2.extend_from_slice(&key_for(1, KEY));
    v2.extend_from_slice(&value_for(1, VAL));
    let mid = DhtCheckpoint::from_bytes(&v2).expect("v2 parse");
    assert_eq!(mid.buckets_per_rank, Some(per_rank));
    assert_eq!(mid.nranks, Some(2));
    assert_eq!(mid.entries.len(), 1);
    assert_eq!(mid.entry_meta, vec![Meta::OCCUPIED], "v2 loads unstamped");
    let mut from_v2 = mid.restore(Variant::LockFree, 1, 64 * 1024);
    assert_eq!(from_v2[0].read(&key_for(1, KEY)), Some(value_for(1, VAL)));

    // hand-built v1 payload: one entry, legacy magic, no geometry
    let mut v1 = Vec::new();
    v1.extend_from_slice(b"DHTCKPT1");
    v1.push(2); // lock-free
    v1.extend_from_slice(&(KEY as u32).to_le_bytes());
    v1.extend_from_slice(&(VAL as u32).to_le_bytes());
    v1.extend_from_slice(&1u64.to_le_bytes());
    v1.extend_from_slice(&key_for(1, KEY));
    v1.extend_from_slice(&value_for(1, VAL));
    let legacy = DhtCheckpoint::from_bytes(&v1).expect("v1 parse");
    assert_eq!(legacy.buckets_per_rank, None);
    assert_eq!(legacy.nranks, None);
    assert_eq!(legacy.entries.len(), 1);
    assert_eq!(legacy.entry_meta, vec![Meta::OCCUPIED], "v1 loads unstamped");
    // v1 checkpoints carry no geometry: strict restore cannot reject
    let restored = legacy
        .restore_strict(Variant::LockFree, 1, 64 * 1024)
        .expect("v1 restores anywhere");
    assert_eq!(restored.len(), 1);

    // strict restore: too small -> clear error; adequate -> ok
    let bucket =
        mpi_dht::dht::BucketLayout::new(Variant::LockFree, KEY, VAL).size();
    let err = ckpt
        .restore_strict(Variant::LockFree, 1, 8 * bucket)
        .unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("capacity mismatch"), "{msg}");
    assert!(msg.contains("grow"), "actionable message: {msg}");
    let mut ok = ckpt
        .restore_strict(Variant::LockFree, 4, 64 * 1024)
        .expect("adequate target");
    assert_eq!(ok[0].read(&key_for(3, KEY)), Some(value_for(3, VAL)));
}
