//! Topology-aware network model, end to end (DESIGN.md §13).
//!
//! Contract under test:
//!
//! * the crossbar (default) is *bit-identical* to the historical flat
//!   model for every DHT variant, whatever the link-model/background
//!   dials say — upgrading the network layer must not move a single
//!   pinned timing;
//! * a dedicated (full-bisection, idle) fat tree agrees with the flat
//!   model within the 10 % calibration band at paper-scale rank counts;
//! * a tapered fat tree under heavy background load diverges hard at
//!   4k ranks — the congestion knee the flat model cannot see;
//! * the reply-path fix: same-node delegated ops are strictly cheaper
//!   than cross-node ones (they no longer pay the full wire), and
//!   delegated replies occupy the owner node's NIC.

use mpi_dht::bench::{run_kv, Dist, KvCfg, KvResult, Mode};
use mpi_dht::dht::Variant;
use mpi_dht::net::{LinkModel, NetConfig, Topology};

fn kv(nranks: u32, ops: u64, dist: Dist, mode: Mode) -> KvCfg {
    let mut cfg = KvCfg::new(nranks, ops, dist, mode);
    // fixed-size windows keep memory flat at the 4k-rank scale below
    cfg.win_bytes = 32 * 1024;
    cfg
}

/// Digest of everything timing-dependent in a run.  Two runs with equal
/// digests took the same simulated schedule, event for event.
fn digest(r: &KvResult) -> (u64, u64, u64, u128, u64, u64, u64, u64) {
    (
        r.sim.duration,
        r.sim.events,
        r.sim.net_messages,
        r.sim.net_bytes,
        r.read_lat_p50,
        r.read_lat_p95,
        r.write_lat_p50,
        r.write_lat_p95,
    )
}

/// The crossbar must ignore the link model and background load: it has
/// dedicated per-pair capacity, so those dials have nothing to act on.
/// This is also the regression pin that the topology refactor left the
/// flat model bit-identical for all four variants.
#[test]
fn crossbar_is_bit_identical_across_link_dials() {
    for variant in Variant::ALL {
        let cfg = kv(256, 150, Dist::Uniform, Mode::WriteThenRead);
        let baseline = run_kv(variant, NetConfig::pik_ndr(), cfg.clone());
        for (model, bg) in [
            (LinkModel::Constant, 0.0),
            (LinkModel::Shared, 0.0),
            (LinkModel::Shared, 0.9),
        ] {
            let mut net = NetConfig::pik_ndr();
            net.link_model = model;
            net.bg_load = bg;
            let run = run_kv(variant, net, cfg.clone());
            assert_eq!(
                digest(&baseline),
                digest(&run),
                "{variant:?} drifted under crossbar with {model:?}/bg={bg}"
            );
        }
    }
}

/// Calibration band: at 128 ranks on a *dedicated full-bisection* fat
/// tree (idle links, no taper), throughput must agree with the flat
/// model within 10 %.  `ranks_per_node` is forced to 16 so 128 ranks
/// span 8 nodes — at PIK's dense 128-ranks/node mapping the run would
/// fit on one node and the fabric would never be exercised.
#[test]
fn dedicated_fat_tree_matches_flat_within_ten_percent() {
    let mut flat = NetConfig::pik_ndr();
    flat.ranks_per_node = 16;
    let mut ftree = flat.clone();
    ftree.topology = Topology::FatTree { pod: 0, oversub: 1 };
    ftree.link_model = LinkModel::Shared;

    let cfg = kv(128, 400, Dist::Uniform, Mode::WriteThenRead);
    let a = run_kv(Variant::LockFree, flat, cfg.clone());
    let b = run_kv(Variant::LockFree, ftree, cfg);
    for (label, f, t) in [
        ("read", a.read_mops, b.read_mops),
        ("write", a.write_mops, b.write_mops),
    ] {
        let dev = (t - f).abs() / f.max(1e-12);
        assert!(
            dev < 0.10,
            "{label}: dedicated fat tree {t:.3} vs flat {f:.3} Mops \
             ({:.1}% off; calibration band is 10%)",
            dev * 100.0
        );
    }
}

/// The congestion knee (the tentpole's reason to exist): at 4096 ranks
/// over an 8:1 tapered fat tree whose links are 95 % consumed by other
/// jobs, lock-free reads fall measurably below the flat extrapolation —
/// and the run tells us *where* it hurts (a saturated shared link).
/// A dedicated NDR fabric never binds for ~200-byte KV traffic; the
/// taper+load regime is what production batch schedulers actually give.
#[test]
fn tapered_fat_tree_diverges_from_flat_at_4k_ranks() {
    let flat = NetConfig::pik_ndr();
    let mut ftree = flat.clone();
    ftree.topology = Topology::FatTree { pod: 8, oversub: 8 };
    ftree.link_model = LinkModel::Shared;
    ftree.bg_load = 0.95;

    let cfg = kv(4_096, 32, Dist::Uniform, Mode::WriteThenRead);
    let a = run_kv(Variant::LockFree, flat, cfg.clone());
    let b = run_kv(Variant::LockFree, ftree, cfg);
    assert!(
        b.read_mops < 0.75 * a.read_mops,
        "expected a congestion knee: fat-tree {:.2} vs flat {:.2} Mops",
        b.read_mops,
        a.read_mops
    );
    let (label, util) = b.sim.peak_link().expect("fabric has links");
    assert!(
        util > 0.5,
        "knee should come with a saturated link, got {label} at {util:.2}"
    );
    // and the flat run has no links at all to blame
    assert!(a.sim.peak_link().is_none());
}

/// Reply-path bugfix regression: a delegated DHT whose two ranks share
/// a node must be strictly faster than the same workload with the ranks
/// on different nodes.  Before the fix both cases charged the full
/// cross-node `wire_ns` on every RPC/mailbox reply, making co-located
/// delegation look exactly as expensive as remote delegation.
#[test]
fn same_node_delegated_ops_cheaper_than_cross_node() {
    let mut same = NetConfig::pik_ndr(); // 128 ranks/node: both on node 0
    same.ranks_per_node = 128;
    let mut cross = NetConfig::pik_ndr();
    cross.ranks_per_node = 1; // one rank per node: every pair crosses

    let cfg = kv(2, 400, Dist::Uniform, Mode::WriteThenRead);
    let a = run_kv(Variant::Delegated, same, cfg.clone());
    let b = run_kv(Variant::Delegated, cross, cfg);
    // p95 isolates the remote-owner ops (p50 can land on self-owned ones)
    assert!(
        a.read_lat_p95 < b.read_lat_p95,
        "same-node delegated reads should be cheaper: {} vs {} ns",
        a.read_lat_p95,
        b.read_lat_p95
    );
    assert!(
        a.write_lat_p95 < b.write_lat_p95,
        "same-node delegated writes should be cheaper: {} vs {} ns",
        a.write_lat_p95,
        b.write_lat_p95
    );
    assert!(a.read_mops > b.read_mops);
}

/// Reply-path bugfix, resource side: under a hot-key storm the owner
/// node's NIC must show nonzero utilization — replies are real messages
/// serialized on the server NIC, not free teleports.
#[test]
fn delegated_replies_occupy_owner_nic_under_hot_key_storm() {
    let cfg = kv(256, 300, Dist::HotKey, Mode::Mixed { read_percent: 95 });
    let res = run_kv(Variant::Delegated, NetConfig::pik_ndr(), cfg);
    let peak = res
        .sim
        .nic_util
        .iter()
        .cloned()
        .fold(0.0f64, f64::max);
    assert!(
        peak > 0.02,
        "owner NIC should be visibly busy answering the storm, got {peak:.4}"
    );
    assert!(res.stats.mailbox_ops > 0, "storm must ride the mailboxes");
}
