//! Property-based tests (proptest-lite, DESIGN.md §7): coordinator
//! invariants that must hold for *every* random schedule, geometry and
//! key set — routing, batching/probing, and state management.

use std::collections::HashMap;

use mpi_dht::dht::bucket::{record_crc, Meta};
use mpi_dht::dht::{
    Addressing, BucketLayout, Dht, DhtCheckpoint, DhtOutcome, Variant,
};
use mpi_dht::poet::key::round_sig;
use mpi_dht::util::prop::{prop_check, G};
use mpi_dht::util::zipf::Zipf;
use mpi_dht::{prop_assert, prop_assert_eq};

/// Routing: target rank and candidate indices are always in range, stable,
/// and the index window count follows the paper's formula.
#[test]
fn prop_addressing_invariants() {
    prop_check("addressing-invariants", 300, |g: &mut G| {
        let nranks = g.u64_in(1..2048) as u32;
        let buckets = g.u64_in(1..50_000_000);
        let a = Addressing::new(nranks, buckets);
        // smallest n with B <= 2^(8n)
        let n = a.index_bytes();
        prop_assert!(buckets as u128 <= 1u128 << (8 * n));
        if n > 1 {
            prop_assert!(buckets as u128 > 1u128 << (8 * (n - 1)));
        }
        prop_assert_eq!(a.num_indices(), 8 - n + 1);
        let key = g.bytes(80);
        let h = a.hash(&key);
        prop_assert!(a.target(h) < nranks);
        let idx = a.indices(h);
        prop_assert_eq!(idx.len(), a.num_indices() as usize);
        for i in &idx {
            prop_assert!(*i < buckets);
        }
        prop_assert_eq!(a.indices(h), idx);
        Ok(())
    });
}

/// Replica placement (DESIGN.md §9): k replicas always land on k
/// distinct in-range ranks with the primary first, a degenerate
/// `k >= nranks` clamps instead of panicking, and placement is stable
/// across `rescale` epochs (so replication composes with the elastic
/// resize without cross-rank movement).
#[test]
fn prop_replica_placement() {
    prop_check("replica-placement", 300, |g: &mut G| {
        let nranks = g.u64_in(1..2048) as u32;
        let buckets = g.u64_in(1..1_000_000);
        let k_req = g.u64_in(1..4096) as u32; // may exceed nranks
        let a = Addressing::new(nranks, buckets).with_replicas(k_req);
        let k = a.replicas();
        prop_assert_eq!(k, k_req.clamp(1, nranks));
        let key = g.bytes(80);
        let h = a.hash(&key);
        let targets = a.replica_targets(h);
        prop_assert_eq!(targets.len(), k as usize);
        prop_assert_eq!(targets[0], a.target(h));
        for &t in &targets {
            prop_assert!(t < nranks);
        }
        let distinct: std::collections::HashSet<u32> =
            targets.iter().copied().collect();
        prop_assert_eq!(distinct.len(), k as usize);
        // stable under rescale (elastic resize epochs)
        let b = a.rescale(g.u64_in(1..1_000_000));
        prop_assert_eq!(b.replicas(), k);
        for (r, &t) in targets.iter().enumerate() {
            prop_assert_eq!(b.replica_target(h, r as u32), t);
        }
        Ok(())
    });
}

/// Fuzz `DhtCheckpoint::from_bytes`: a pristine v1/v2/v3 buffer parses
/// and round-trips (v3 with its tenant/age meta words intact); mutated,
/// truncated, or extended buffers must return `None` or a sane
/// checkpoint — never panic.
#[test]
fn prop_checkpoint_from_bytes_never_panics() {
    prop_check("checkpoint-fuzz", 300, |g: &mut G| {
        let key_len = g.usize_in(1..40);
        let val_len = g.usize_in(1..40);
        let n = g.usize_in(0..16);
        let entries: Vec<(Vec<u8>, Vec<u8>)> =
            (0..n).map(|_| (g.bytes(key_len), g.bytes(val_len))).collect();
        let version = g.u64_in(0..3); // 0 = v1, 1 = v2, 2 = v3
        let metas: Vec<u64> = (0..n)
            .map(|_| {
                Meta::stamp(
                    g.u64_in(0..256) as u32,
                    g.u64_in(0..1 << 24) as u32,
                    g.bool(),
                )
            })
            .collect();
        let bytes = match version {
            2 => DhtCheckpoint {
                variant: *g.pick(&Variant::ALL),
                key_len,
                val_len,
                buckets_per_rank: Some(g.u64_in(1..1_000_000)),
                nranks: Some(g.u64_in(1..1024) as u32),
                entries: entries.clone(),
                entry_meta: metas.clone(),
            }
            .to_bytes(),
            1 => {
                // hand-built v2: the v1 head plus geometry, meta-less
                // records — what a pre-v3 build serialized
                let mut b = Vec::new();
                b.extend_from_slice(b"DHTCKPT2");
                b.push(g.u64_in(0..4) as u8);
                b.extend_from_slice(&(key_len as u32).to_le_bytes());
                b.extend_from_slice(&(val_len as u32).to_le_bytes());
                b.extend_from_slice(&g.u64_in(1..1_000_000).to_le_bytes());
                b.extend_from_slice(
                    &(g.u64_in(1..1024) as u32).to_le_bytes(),
                );
                b.extend_from_slice(&(n as u64).to_le_bytes());
                for (k, v) in &entries {
                    b.extend_from_slice(k);
                    b.extend_from_slice(v);
                }
                b
            }
            _ => {
                // hand-built legacy v1: magic, variant, lens, count
                let mut b = Vec::new();
                b.extend_from_slice(b"DHTCKPT1");
                b.push(g.u64_in(0..3) as u8);
                b.extend_from_slice(&(key_len as u32).to_le_bytes());
                b.extend_from_slice(&(val_len as u32).to_le_bytes());
                b.extend_from_slice(&(n as u64).to_le_bytes());
                for (k, v) in &entries {
                    b.extend_from_slice(k);
                    b.extend_from_slice(v);
                }
                b
            }
        };
        // pristine buffer parses and round-trips its entries
        let cp = DhtCheckpoint::from_bytes(&bytes)
            .ok_or("pristine checkpoint must parse")?;
        prop_assert_eq!(cp.key_len, key_len);
        prop_assert_eq!(cp.val_len, val_len);
        prop_assert_eq!(&cp.entries, &entries);
        prop_assert_eq!(cp.buckets_per_rank.is_some(), version >= 1);
        if version == 2 {
            // the tenant/age meta words survive the round trip
            prop_assert_eq!(&cp.entry_meta, &metas);
        } else {
            // meta-less images restore unstamped (tenant 0, age 0)
            prop_assert!(
                cp.entry_meta.iter().all(|&m| m == Meta::OCCUPIED),
                "v1/v2 entries must restore under the unstamped meta"
            );
        }
        match g.u64_in(0..4) {
            0 => {
                // strict truncation: the exact-length check must reject
                let cut = g.usize_in(0..bytes.len());
                prop_assert!(
                    DhtCheckpoint::from_bytes(&bytes[..cut]).is_none(),
                    "truncated at {cut}/{} must not parse",
                    bytes.len()
                );
            }
            1 => {
                // header byte flip: parse may fail or yield a different
                // but sane checkpoint — it must never panic.  The record
                // stride follows whatever magic the flip left behind.
                let mut bad = bytes.clone();
                let pos = g.usize_in(0..bad.len().min(29));
                bad[pos] ^= 1u8 << g.u64_in(0..8);
                if let Some(c) = DhtCheckpoint::from_bytes(&bad) {
                    prop_assert!(c.key_len > 0 && c.val_len > 0);
                    let head =
                        if &bad[..8] == b"DHTCKPT1" { 25 } else { 37 };
                    let rec = c.key_len
                        + c.val_len
                        + if &bad[..8] == b"DHTCKPT3" { 8 } else { 0 };
                    prop_assert_eq!(c.entries.len() * rec + head, bad.len());
                }
            }
            2 => {
                // trailing garbage: the exact-length check must reject
                let mut bad = bytes.clone();
                bad.extend(g.bytes(g.usize_in(1..16)));
                prop_assert!(
                    DhtCheckpoint::from_bytes(&bad).is_none(),
                    "extended buffer must not parse"
                );
            }
            _ => {
                // forged v3 meta: clearing OCCUPIED or setting INVALID on
                // any record must be rejected, not smuggled past restore
                if version == 2 && n > 0 {
                    let mut bad = bytes.clone();
                    let i = g.usize_in(0..n);
                    let rec = key_len + val_len + 8;
                    let off = 37 + i * rec + rec - 8;
                    let m = u64::from_le_bytes(
                        bad[off..off + 8].try_into().unwrap(),
                    );
                    let forged = if g.bool() {
                        m & !Meta::OCCUPIED // un-occupied
                    } else {
                        m | Meta::INVALID // invalidated
                    };
                    bad[off..off + 8]
                        .copy_from_slice(&forged.to_le_bytes());
                    prop_assert!(
                        DhtCheckpoint::from_bytes(&bad).is_none(),
                        "forged meta on record {i} must not parse"
                    );
                }
            }
        }
        Ok(())
    });
}

/// Read-your-writes: any serialized schedule of writes/reads on any
/// variant agrees with a HashMap model, modulo cache evictions (which are
/// only allowed at full candidate sets).
#[test]
fn prop_model_equivalence_all_variants() {
    prop_check("model-equivalence", 60, |g: &mut G| {
        let variant = *g.pick(&Variant::ALL);
        let nranks = g.u64_in(1..7) as u32;
        let win_kb = g.u64_in(32..256) as usize;
        let mut h = Dht::create_poet(variant, nranks, win_kb * 1024);
        let mut model: HashMap<u64, u64> = HashMap::new();
        let key_space = g.u64_in(4..800);
        let nops = g.usize_in(50..600);
        let mut evictions = 0u64;
        for _ in 0..nops {
            let id = g.u64_in(0..key_space);
            let rank = g.u64_in(0..nranks as u64) as usize;
            if g.chance(0.6) {
                let version = g.u64();
                let key = mpi_dht::bench::keys::key_for(id, 80);
                let val = mpi_dht::bench::keys::value_for(version, 104);
                if h[rank].write(&key, &val) == DhtOutcome::WriteEvict {
                    evictions += 1;
                }
                model.insert(id, version);
            } else {
                let key = mpi_dht::bench::keys::key_for(id, 80);
                let got = h[rank].read(&key);
                match (got, model.get(&id)) {
                    (Some(v), Some(ver)) => {
                        // value must be the latest written version OR the
                        // bucket was evicted and repopulated... since ids
                        // map to unique keys, any hit must be the exact
                        // latest version
                        prop_assert_eq!(
                            v,
                            mpi_dht::bench::keys::value_for(*ver, 104)
                        );
                    }
                    (Some(_), None) => {
                        return Err("hit for never-written key".into())
                    }
                    (None, Some(_)) => {
                        // allowed only if something was evicted
                        prop_assert!(
                            evictions > 0,
                            "miss without any eviction (variant {variant:?})"
                        );
                    }
                    (None, None) => {}
                }
            }
        }
        Ok(())
    });
}

/// The CRC detects any single-bit corruption of any record geometry.
#[test]
fn prop_crc_detects_bit_flips() {
    prop_check("crc-detects-corruption", 200, |g: &mut G| {
        let klen = g.usize_in(1..200);
        let vlen = g.usize_in(1..300);
        let l = BucketLayout::new(Variant::LockFree, klen, vlen);
        let key = g.bytes(klen);
        let val = g.bytes(vlen);
        let rec = l.encode_record(&key, &val);
        prop_assert!(l.crc_ok(&rec));
        // flip one random bit inside the key or value region
        let k0 = l.key_off() - l.meta_off();
        let payload_positions: Vec<usize> = (k0..k0 + klen)
            .chain(l.val_off() - l.meta_off()..l.val_off() - l.meta_off() + vlen)
            .collect();
        let pos = *g.pick(&payload_positions);
        let bit = 1u8 << g.u64_in(0..8);
        let mut bad = rec.clone();
        bad[pos] ^= bit;
        prop_assert!(!l.crc_ok(&bad), "flip at {pos} bit {bit} undetected");
        prop_assert!(record_crc(&key, &val) == l.crc_of(&rec));
        Ok(())
    });
}

/// The allocation-free `encode_into` path is byte-identical to the
/// original `encode_record` for every variant and (key,val) geometry —
/// including the CRC word — and the zero-copy accessors decode it.
#[test]
fn prop_encode_into_matches_encode_record() {
    prop_check("encode-into-equivalence", 300, |g: &mut G| {
        let variant = *g.pick(&Variant::ALL);
        let klen = g.usize_in(1..200);
        let vlen = g.usize_in(1..300);
        let l = BucketLayout::new(variant, klen, vlen);
        let mut scratch = Vec::new();
        // reuse the scratch across records: stale bytes from a previous
        // encoding must never leak into the next one
        for _ in 0..g.usize_in(1..4) {
            let key = g.bytes(klen);
            let val = g.bytes(vlen);
            let reference = l.encode_record(&key, &val);
            l.encode_into(&key, &val, &mut scratch);
            prop_assert_eq!(&scratch, &reference);
            // deferred-CRC + batch-fill path agrees byte for byte too
            let mut nocrc = Vec::new();
            l.encode_into_nocrc(&key, &val, &mut nocrc);
            let mut batch = vec![nocrc];
            l.fill_crc_batch(&mut batch);
            prop_assert_eq!(&batch[0], &reference);
            // zero-copy decode round-trips (incl. the CRC word)
            prop_assert_eq!(l.key_of(&scratch), &key[..]);
            prop_assert_eq!(l.val_of(&scratch), &val[..]);
            if variant == Variant::LockFree {
                prop_assert!(l.crc_ok(&scratch));
                prop_assert_eq!(l.crc_of(&scratch), record_crc(&key, &val));
            }
        }
        Ok(())
    });
}

/// Significant-digit rounding: idempotent, monotone in digits, magnitude
/// preserving, and sign preserving.
#[test]
fn prop_round_sig() {
    prop_check("round-sig", 500, |g: &mut G| {
        let v = match g.u64_in(0..4) {
            0 => g.f64_in(-1.0..1.0),
            1 => g.f64_in(-1e-9..1e-9),
            2 => g.f64_in(-1e9..1e9),
            _ => 0.0,
        };
        let d = g.u64_in(1..12) as u32;
        let r = round_sig(v, d);
        prop_assert_eq!(round_sig(r, d), r);
        prop_assert!(r.signum() == v.signum() || r == 0.0 || v == 0.0);
        if v != 0.0 {
            let rel = ((r - v) / v).abs();
            prop_assert!(
                rel <= 0.5 * 10f64.powi(-(d as i32 - 1)) + 1e-12,
                "v={v} d={d} r={r} rel={rel}"
            );
        }
        Ok(())
    });
}

/// Zipfian sampler: all draws in range; empirical top-1 frequency close to
/// the analytic 1/zeta(n, theta); skew monotone in theta.
#[test]
fn prop_zipf_distribution() {
    prop_check("zipf-distribution", 12, |g: &mut G| {
        let n = g.u64_in(100..5_000);
        let z = Zipf::new(n, 0.99).unscrambled();
        let mut rng = mpi_dht::util::rng::Rng::new(g.u64());
        let draws = 60_000;
        let mut top = 0u64;
        for _ in 0..draws {
            let s = z.sample(&mut rng);
            prop_assert!(s < n);
            if s == 0 {
                top += 1;
            }
        }
        let mut zeta = 0.0;
        for i in 1..=n {
            zeta += 1.0 / (i as f64).powf(0.99);
        }
        let expect = draws as f64 / zeta;
        prop_assert!(
            (top as f64) > 0.6 * expect && (top as f64) < 1.5 * expect,
            "top {top} expect {expect:.1} (n={n})"
        );
        Ok(())
    });
}

/// POET key packing: round trip and rounding stability — two states equal
/// after rounding yield the same key; states differing beyond rounding
/// yield different keys.
#[test]
fn prop_cell_keys() {
    use mpi_dht::poet::key::{cell_key, pack_row, unpack_value};
    prop_check("cell-keys", 300, |g: &mut G| {
        let digits = g.u64_in(2..9) as u32;
        let mut row = [0.0f64; 10];
        for v in row.iter_mut() {
            *v = g.f64_in(1e-8..1e-2);
        }
        row[9] = g.f64_in(1.0..1e4);
        let k1 = cell_key(&row, digits);
        prop_assert_eq!(k1.len(), 80);
        // sub-resolution perturbation keeps the key
        let mut near = row;
        near[0] *= 1.0 + 1e-12;
        prop_assert_eq!(cell_key(&near, digits.min(6)), cell_key(&row, digits.min(6)));
        // value packing round trip
        let mut out = [0.0f64; 13];
        for v in out.iter_mut() {
            *v = g.f64_in(-1e3..1e3);
        }
        prop_assert_eq!(unpack_value(&pack_row(&out)), out);
        Ok(())
    });
}

/// Histogram percentiles are monotone and bounded by min/max.
#[test]
fn prop_histogram_monotone() {
    use mpi_dht::metrics::Histogram;
    prop_check("histogram-monotone", 100, |g: &mut G| {
        let mut h = Histogram::new();
        let n = g.usize_in(1..2000);
        let mut lo = u64::MAX;
        let mut hi = 0;
        for _ in 0..n {
            let v = g.u64_in(1..10_000_000_000);
            lo = lo.min(v);
            hi = hi.max(v);
            h.record(v);
        }
        let p25 = h.percentile(25.0);
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        prop_assert!(p25 <= p50 && p50 <= p99);
        // bucketing error is bounded by one bucket width (~25 %)
        prop_assert!(p99 <= hi + hi / 4 + 1);
        prop_assert!(p25 + p25 / 4 + 1 >= lo);
        Ok(())
    });
}

/// Key-ladder monotonicity (DESIGN.md §10): states sharing the
/// fine-level key share *every* coarser-level key (the property that
/// makes coarse back-fill sound), and the relative error any level
/// introduces is bounded by its significant-digit budget.
#[test]
fn prop_ladder_monotone_and_bounded() {
    use mpi_dht::poet::key::{ladder_key, ladder_rel_err, LadderCfg};
    prop_check("ladder-monotone", 300, |g: &mut G| {
        let digits = g.u64_in(2..8) as u32;
        let levels = g.u64_in(1..4) as u32;
        let cfg = LadderCfg { digits, levels, rel_tol: 1.0 };
        let mut row = [0.0f64; 10];
        for v in row.iter_mut() {
            *v = g.f64_in(1e-8..1e-2);
        }
        row[9] = g.f64_in(1.0..1e4);
        // perturb one species near (and sometimes across) the fine
        // level's rounding resolution, so both key-equal and key-unequal
        // siblings are generated — including boundary cases where direct
        // re-rounding of the raw value would break monotonicity
        let mut near = row;
        let i = g.usize_in(0..9);
        let scale = match g.u64_in(0..3) {
            0 => 1e-12,
            1 => 10f64.powi(-(digits as i32)),
            _ => 10f64.powi(-(digits as i32) + 1),
        };
        near[i] *= 1.0 + g.f64_in(-1.0..1.0) * scale;
        if ladder_key(&near, &cfg, 0) == ladder_key(&row, &cfg, 0) {
            for level in 1..=levels {
                prop_assert_eq!(
                    ladder_key(&near, &cfg, level),
                    ladder_key(&row, &cfg, level),
                    "fine-equal states diverged at level {level} \
                     (digits {digits}, species {i})"
                );
            }
        }
        for level in 0..=levels {
            let k = digits.saturating_sub(level).max(1);
            let e = ladder_rel_err(&row, &cfg, level);
            let bound = 0.57 * 10f64.powi(1 - k as i32);
            prop_assert!(
                e <= bound,
                "level {level} err {e} above bound {bound}"
            );
        }
        Ok(())
    });
}

/// Self-healing invariant (DESIGN.md §11): after ANY schedule of kills
/// and revives that never exceeds k-1 simultaneous deaths and drains
/// repair between transitions (so every shard always keeps a live
/// copy), every key ends with copies on ALL of its k distinct live
/// successor ranks — verified by isolating each claimed holder and
/// reading through it alone.  Values are never foreign, even through
/// stale-but-valid copies on revived ranks.
#[test]
fn prop_repair_restores_k_live_replicas() {
    use mpi_dht::bench::keys::{key_for, value_for};
    prop_check("repair-k-live-replicas", 15, |g: &mut G| {
        let nranks = g.u64_in(3..6) as u32;
        let k = 2u32;
        let mut h = Dht::create(Variant::LockFree, nranks, 64 * 1024, 16, 32);
        for hh in h.iter_mut() {
            hh.set_replicas(k);
            hh.set_repair(true);
        }
        let nkeys = g.u64_in(40..120);
        let keys: Vec<Vec<u8>> =
            (0..nkeys).map(|i| key_for(i, 16)).collect();
        let vals: Vec<Vec<u8>> =
            (0..nkeys).map(|i| value_for(i * 7, 32)).collect();
        h[0].write_batch(&keys, &vals);
        let mut dead = vec![false; nranks as usize];
        for _ in 0..g.usize_in(1..6) {
            // maybe revive the currently-dead rank
            if let Some(d) = dead.iter().position(|&x| x) {
                if g.bool() {
                    h[0].set_rank_failed(d as u32, false);
                    dead[d] = false;
                }
            }
            // maybe kill one rank (never more than k-1 = 1 at a time)
            if !dead.iter().any(|&x| x) && g.chance(0.8) {
                let r = g.u64_in(0..nranks as u64) as usize;
                h[0].set_rank_failed(r as u32, true);
                dead[r] = true;
            }
            // drain the armed repair pass on every live handle before
            // the next transition — the invariant's precondition
            for (r, hh) in h.iter_mut().enumerate() {
                if !dead[r] {
                    hh.drain_repair();
                    prop_assert!(!hh.repairing(), "pass must complete");
                }
            }
        }
        // freeze: no piggybacked repair during verification reads
        for hh in h.iter_mut() {
            hh.set_repair(false);
        }
        let placements: Vec<Vec<u32>> = {
            let a = &h[0].cfg().addressing;
            keys.iter()
                .map(|key| {
                    a.live_replica_targets(a.hash(key), |r| {
                        dead[r as usize]
                    })
                })
                .collect()
        };
        for ((key, val), targets) in
            keys.iter().zip(vals.iter()).zip(placements.iter())
        {
            prop_assert_eq!(
                targets.len(),
                k as usize,
                "enough live ranks for full replication"
            );
            for &t in targets {
                // isolate rank t: only it can serve this read
                for r in 0..nranks {
                    h[0].set_rank_failed(r, r != t);
                }
                prop_assert_eq!(
                    h[t as usize].read(key).as_ref(),
                    Some(val),
                    "rank {t} must hold a correct copy after repair"
                );
            }
        }
        for r in 0..nranks {
            h[0].set_rank_failed(r, dead[r as usize]);
        }
        Ok(())
    });
}

/// The rank-local L1 never serves a stale value across a resize epoch,
/// and composes with replica failover (DESIGN.md §10): after another
/// handle updates a key and the table resizes, a reader whose L1 cached
/// the old value must observe the update; with the primary rank masked
/// failed, reads still return the correct value.
#[test]
fn prop_l1_fresh_across_resize_and_failover() {
    prop_check("l1-resize-failover", 25, |g: &mut G| {
        let nranks = g.u64_in(2..5) as u32;
        let mut h = Dht::create(Variant::LockFree, nranks, 64 * 1024, 8, 8);
        for hh in h.iter_mut() {
            hh.set_replicas(2);
            hh.set_l1_bytes(16 * 1024);
        }
        let reader = g.u64_in(1..nranks as u64) as usize;
        let key = g.bytes(8);
        let v1 = g.bytes(8);
        h[0].write(&key, &v1);
        prop_assert_eq!(h[reader].read(&key), Some(v1.clone()));
        prop_assert!(
            h[reader].l1_stats().unwrap().fills >= 1,
            "reader's L1 cached the value"
        );
        // another handle updates the key, then the table resizes
        let mut v2 = g.bytes(8);
        while v2 == v1 {
            v2 = g.bytes(8);
        }
        h[0].write(&key, &v2);
        let cur = h[0].buckets_per_rank();
        h[0].resize(cur * 2).unwrap();
        h[0].drain_migration();
        // the reader's next lookup crosses the resize epoch: its L1 copy
        // of v1 must be dropped, not served
        prop_assert_eq!(
            h[reader].read(&key),
            Some(v2.clone()),
            "stale L1 value served across a resize epoch"
        );
        prop_assert!(
            h[reader].l1_stats().unwrap().invalidations >= 1,
            "epoch change must have invalidated the reader's L1"
        );
        // replica failover composes: mask the primary — the warm reader
        // serves from its L1; a forked handle (same budget, empty L1)
        // must go remote, fail over, and still return the fresh value
        let hash = h[reader].cfg().addressing.hash(&key);
        let primary = h[reader].cfg().addressing.replica_target(hash, 0);
        h[reader].set_rank_failed(primary, true);
        prop_assert_eq!(h[reader].read(&key), Some(v2.clone()));
        let mut cold = h[reader].fork();
        prop_assert_eq!(cold.read(&key), Some(v2.clone()));
        prop_assert!(
            cold.stats().failover_reads >= 1,
            "cold read past a failed primary must fail over"
        );
        h[reader].set_rank_failed(primary, false);
        Ok(())
    });
}
