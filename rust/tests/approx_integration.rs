//! Approximate surrogate lookup — end-to-end acceptance (DESIGN.md §10).
//!
//! The headline claim: a 2-level digits ladder plus a rank-local L1
//! strictly lifts the end-of-run hit rate over exact-match lookup at the
//! fine level alone, while the measured max relative error of accepted
//! coarse hits stays within the configured tolerance — on both the DES
//! model and the threaded driver — and composes with replication and
//! deterministic rank kills.

use std::sync::Arc;

use mpi_dht::dht::Variant;
use mpi_dht::net::NetConfig;
use mpi_dht::poet::desmodel::{run_poet_des, PoetDesCfg};
use mpi_dht::poet::{NativeChemistry, PoetConfig, PoetDriver};

/// A small DES config keyed *finer* than the default (digits 6), the
/// regime where exact-match lookup leaves hits on the table.  The flow
/// is 2-D (`cf = [0.4, 0.1]`): pure-x advection keeps whole rows
/// bit-identical, which hides the near-miss structure the ladder is
/// for — diagonal flow gives every front cell its own drifting state.
fn des_cfg(ladder: u32, l1_bytes: usize) -> PoetDesCfg {
    let mut c = PoetDesCfg::scaled(8, Some(Variant::LockFree));
    c.ny = 12;
    c.nx = 24;
    c.steps = 20;
    c.inj_rows = 3;
    c.cf = [0.4, 0.1];
    c.digits = 6;
    c.ladder = ladder;
    c.ladder_rel_tol = 1e-3;
    c.l1_bytes = l1_bytes;
    c.pipeline = 4;
    c
}

/// The acceptance demo: 2-level ladder + L1 vs exact-match at the fine
/// level, same grid, same keys — strictly higher end-of-run hit rate,
/// fewer chemistry calls, measured error within tolerance, physics
/// intact.
#[test]
fn des_ladder_l1_strictly_beats_exact_match() {
    let exact = run_poet_des(des_cfg(0, 0), NetConfig::pik_ndr());
    let approx_cfg = des_cfg(2, 1 << 20);
    let tol = approx_cfg.ladder_rel_tol;
    let steps = approx_cfg.steps;
    let approx = run_poet_des(approx_cfg, NetConfig::pik_ndr());

    // the approximate path actually engaged
    let coarse: u64 = approx.dht.ladder_hits.iter().skip(1).sum();
    assert!(coarse > 0, "no coarse-level hits accepted");
    assert!(approx.dht.l1_hits > 0, "no L1 hits served");
    assert_eq!(approx.dht.nonfinite_skips, 0, "grid stayed finite");

    // end-of-run hit rate strictly higher than exact-match
    let lo = steps.saturating_sub(5);
    let e = exact.hit_rate_over(lo, steps);
    let a = approx.hit_rate_over(lo, steps);
    assert!(
        a > e,
        "end-of-run hit rate must strictly improve: approx {a:.3} vs \
         exact {e:.3}"
    );
    assert!(
        approx.hit_rate() > exact.hit_rate(),
        "whole-run hit rate must improve: {:.3} vs {:.3}",
        approx.hit_rate(),
        exact.hit_rate()
    );
    assert!(
        approx.chem_cells < exact.chem_cells,
        "approximate hits must save chemistry calls: {} vs {}",
        approx.chem_cells,
        exact.chem_cells
    );

    // accuracy channel: accepted error measured, nonzero, within tol
    assert!(approx.dht.max_rel_err > 0.0, "accepted error was measured");
    assert!(
        approx.dht.max_rel_err <= tol,
        "max relative error {} above configured tolerance {tol}",
        approx.dht.max_rel_err
    );
    assert_eq!(approx.dht.mismatches, 0, "no wrong values");

    // physics still emerges within the §5 tolerance of the reference
    let mut refc = PoetDesCfg::scaled(8, None);
    refc.ny = 12;
    refc.nx = 24;
    refc.steps = 20;
    refc.inj_rows = 3;
    refc.cf = [0.4, 0.1];
    let refr = run_poet_des(refc, NetConfig::pik_ndr());
    let d = (approx.max_dolomite - refr.max_dolomite).abs();
    assert!(
        d <= 0.35 * refr.max_dolomite.max(1e-12),
        "dolomite {} vs reference {}",
        approx.max_dolomite,
        refr.max_dolomite
    );
}

/// L1 alone (no ladder): the application hit rate stays essentially
/// unchanged (an L1 hit is a key the remote table also holds, barring
/// eviction) while hot lookups are served without remote traffic.
/// "Essentially": locally served lookups shift simulated event timing,
/// which can flip same-step read/write races on shared fresh keys, so
/// the assertion is a small band rather than bit-equality.
#[test]
fn des_l1_alone_serves_hot_keys_locally() {
    let exact = run_poet_des(des_cfg(0, 0), NetConfig::pik_ndr());
    let l1 = run_poet_des(des_cfg(0, 1 << 20), NetConfig::pik_ndr());
    assert!(l1.dht.l1_hits > 0, "hot keys must be served locally");
    // locally served lookups shift simulated event timing, which can
    // flip same-step read/write races on shared fresh keys — so allow
    // a small tolerance, not bit-equality
    assert!(
        l1.hit_rate() >= exact.hit_rate() - 0.05,
        "L1 must not lose hits: {:.3} vs {:.3}",
        l1.hit_rate(),
        exact.hit_rate()
    );
    assert_eq!(
        l1.hits + l1.misses,
        exact.hits + exact.misses,
        "same number of surrogate lookups"
    );
    assert!(l1.max_dolomite > 0.0);
}

/// Ladder + L1 composed with replication and a deterministic mid-run
/// rank kill (the chaos harness): the run completes, reads fail over,
/// and the accepted-error bound still holds.
#[test]
fn des_approx_survives_rank_kill_with_replication() {
    let mut cfg = des_cfg(2, 1 << 20);
    cfg.replicas = 2;
    let fault_free = run_poet_des(cfg.clone(), NetConfig::pik_ndr());
    let tol = cfg.ladder_rel_tol;
    let mut chaos = cfg.clone();
    chaos.kill_rank_at =
        Some((3, (fault_free.runtime_s * 0.4 * 1e9) as u64));
    let res = run_poet_des(chaos, NetConfig::pik_ndr());
    assert!(res.dht.failover_reads > 0, "failover must have served reads");
    assert!(res.dht.l1_hits > 0, "L1 keeps serving under faults");
    assert!(res.dht.max_rel_err <= tol, "{}", res.dht.max_rel_err);
    assert_eq!(res.dht.mismatches, 0);
    let lo = cfg.steps * 3 / 4;
    let ff = fault_free.hit_rate_over(lo, cfg.steps);
    let ch = res.hit_rate_over(lo, cfg.steps);
    assert!(
        ch + 0.07 >= ff,
        "final-window hit rate {ch:.3} vs fault-free {ff:.3}"
    );
    assert!(res.max_dolomite > 0.0);
}

fn threaded_cfg(ladder: u32, l1_bytes: usize) -> PoetConfig {
    let mut cfg = PoetConfig::small();
    cfg.steps = 30;
    cfg.workers = 2;
    cfg.ny = 12;
    cfg.nx = 36;
    cfg.inj_rows = 3;
    cfg.digits = 6;
    cfg.ladder = ladder;
    cfg.ladder_rel_tol = 1e-3;
    cfg.l1_bytes = l1_bytes;
    cfg
}

/// The threaded driver mirrors the DES result: the ladder + L1 lift the
/// hit rate over exact-match at the same (fine) digits, the physics
/// stays within the reference tolerance, and the error channel is
/// honest.
#[test]
fn threaded_ladder_l1_improves_hit_rate_with_reference_physics() {
    let mut exact_d = PoetDriver::with_default_waters(
        threaded_cfg(0, 0),
        Arc::new(NativeChemistry),
    );
    let exact = exact_d.run_with_dht(Variant::LockFree);
    let mut approx_d = PoetDriver::with_default_waters(
        threaded_cfg(2, 1 << 20),
        Arc::new(NativeChemistry),
    );
    let approx = approx_d.run_with_dht(Variant::LockFree);

    let coarse: u64 = approx.dht.ladder_hits.iter().skip(1).sum();
    assert!(coarse > 0, "coarse-level hits accepted");
    assert!(approx.dht.l1_hits > 0, "L1 engaged");
    assert!(
        approx.hit_rate() > exact.hit_rate(),
        "hit rate {:.3} vs exact {:.3}",
        approx.hit_rate(),
        exact.hit_rate()
    );
    assert!(approx.chem_cells < exact.chem_cells);
    assert!(approx.dht.max_rel_err > 0.0);
    assert!(approx.dht.max_rel_err <= 1e-3);
    assert_eq!(approx.dht.mismatches, 0);

    // physics within the usual tolerance of the no-DHT reference
    let mut ref_d = PoetDriver::with_default_waters(
        threaded_cfg(0, 0),
        Arc::new(NativeChemistry),
    );
    let ref_stats = ref_d.run_reference();
    let d = (approx.max_dolomite - ref_stats.max_dolomite).abs();
    assert!(
        d <= 0.35 * ref_stats.max_dolomite.max(1e-12),
        "dolomite {} vs reference {}",
        approx.max_dolomite,
        ref_stats.max_dolomite
    );
}
