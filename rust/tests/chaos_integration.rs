//! Chaos harness (DESIGN.md §9): deterministic fault injection against
//! the replicated DHT, from raw backend faults up to the coupled POET
//! model.
//!
//! Everything here is deterministic: the DES backend replays identical
//! event schedules for identical configs (kill instants are derived from
//! a fault-free run's simulated duration, not wall time), and the shm
//! tests use the explicit failed-rank mask — so any failure reproduces
//! exactly from the config in the log.

use mpi_dht::bench::keys::{key_for, value_for};
use mpi_dht::dht::{Dht, DhtCheckpoint, EvictPolicy, Variant};
use mpi_dht::net::{NetConfig, Network};
use mpi_dht::poet::desmodel::{run_poet_des, PoetDesCfg};
use mpi_dht::rma::sim::SimRma;
use mpi_dht::rma::FaultPlan;

const KEY: usize = 16;
const VAL: usize = 32;
const KEYS: u64 = 200;

fn sim_handles(variant: Variant, nranks: u32, k: u32) -> Vec<Dht<SimRma>> {
    let net = Network::new(NetConfig::pik_ndr(), nranks);
    let mut h =
        Dht::create_sim(variant, nranks, 256 * 1024, KEY, VAL, net, 8);
    for hh in h.iter_mut() {
        hh.set_replicas(k);
    }
    h
}

fn keyset() -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
    (
        (0..KEYS).map(|i| key_for(i, KEY)).collect(),
        (0..KEYS).map(|i| value_for(i * 3, VAL)).collect(),
    )
}

/// With k = 2 every key stays readable after a rank kill: reads whose
/// primary died fail over to the replica, on every variant.
#[test]
fn replicated_reads_survive_rank_kill_on_sim() {
    for variant in Variant::ALL {
        let mut h = sim_handles(variant, 4, 2);
        let (keys, vals) = keyset();
        h[0].write_batch(&keys, &vals);
        // kill at the current instant: the failure detector already
        // reports rank 1 dead when the read SMs are built, so dead
        // primaries are skipped without traffic
        let at = h[0].sim_time();
        h[0].set_fault_plan(FaultPlan::default().kill_rank_at(1, at));
        let got = h[2].read_batch(&keys);
        let mut hits = 0u64;
        for ((k, v), g) in keys.iter().zip(vals.iter()).zip(got.iter()) {
            if let Some(gv) = g {
                assert_eq!(gv, v, "{variant:?}: wrong value for {:?}", &k[..2]);
                hits += 1;
            }
        }
        assert!(
            hits >= KEYS - 2,
            "{variant:?}: only {hits}/{KEYS} served after the kill"
        );
        let s = h[2].stats();
        assert!(s.failover_reads > 0, "{variant:?}: failover must engage");
        assert_eq!(
            s.replica_divergence, 0,
            "{variant:?}: skipping a detected-dead primary is not divergence"
        );
    }
}

/// Detector lag: a kill landing *after* the read SMs were built means
/// the dead primary is still probed — its degraded miss then replica
/// hit is indistinguishable from divergence and is counted as such
/// (the honest semantics of an asynchronous failure detector).  Every
/// key is still served correctly.
#[test]
fn detector_lag_kill_counts_as_divergence_but_serves_reads() {
    let mut h = sim_handles(Variant::LockFree, 4, 2);
    let (keys, vals) = keyset();
    h[0].write_batch(&keys, &vals);
    // kill strictly in the future: SMs built now still probe rank 1
    let at = h[0].sim_time() + 1;
    h[0].set_fault_plan(FaultPlan::default().kill_rank_at(1, at));
    let got = h[2].read_batch(&keys);
    let mut hits = 0u64;
    for (v, g) in vals.iter().zip(got.iter()) {
        if let Some(gv) = g {
            assert_eq!(gv, v, "never a foreign value");
            hits += 1;
        }
    }
    assert!(hits >= KEYS - 2, "only {hits}/{KEYS} served via failover");
    let s = h[2].stats();
    assert!(s.failover_reads > 0);
    assert!(
        s.replica_divergence > 0,
        "in-flight probes of the dying rank read as diverged"
    );
}

/// Without replication the dead shard is simply lost: its keys read as
/// misses (never wrong values), everything else is still served.
#[test]
fn unreplicated_kill_loses_exactly_the_dead_shard() {
    let mut h = sim_handles(Variant::LockFree, 4, 1);
    let (keys, vals) = keyset();
    h[0].write_batch(&keys, &vals);
    let at = h[0].sim_time() + 1;
    h[0].set_fault_plan(FaultPlan::default().kill_rank_at(1, at));
    let got = h[2].read_batch(&keys);
    let mut lost = 0u64;
    for (i, g) in got.iter().enumerate() {
        let a = &h[2].cfg().addressing;
        if a.target(a.hash(&keys[i])) == 1 {
            assert!(g.is_none(), "dead shard must read as empty");
            lost += 1;
        } else if let Some(gv) = g {
            assert_eq!(gv, &vals[i]);
        }
    }
    assert!(lost > 0, "some keys lived on the killed rank");
    assert_eq!(h[2].stats().failover_reads, 0, "k = 1: nowhere to go");
}

/// Torn-write injection: the truncated record's CRC cannot match, so the
/// lock-free read returns miss/corrupt — never a half-written value —
/// and a later write repairs the bucket.
#[test]
fn torn_write_is_caught_by_the_crc_guard() {
    let net = Network::new(NetConfig::pik_ndr(), 1);
    let mut h =
        Dht::create_sim(Variant::LockFree, 1, 64 * 1024, KEY, VAL, net, 4);
    // the first Put applied at rank 0 is the first write's record put;
    // tear it mid-value (record = meta 8 + key 16 + val 32 + crc 8)
    h[0].set_fault_plan(FaultPlan::default().torn_put(0, 0, 40));
    let key = key_for(7, KEY);
    h[0].write(&key, &value_for(7, VAL));
    assert_eq!(h[0].fault_stats().torn_puts, 1, "the tear was injected");
    assert_eq!(h[0].read(&key), None, "half-record must not be served");
    let s = h[0].stats();
    assert!(
        s.mismatches >= 1,
        "the CRC guard must have caught the tear"
    );
    // a fresh write reuses the invalidated bucket
    h[0].write(&key, &value_for(9, VAL));
    assert_eq!(h[0].read(&key), Some(value_for(9, VAL)));
}

/// Delay and drop windows slow replicated traffic down without changing
/// any outcome (the modelled transport is reliable).
#[test]
fn delay_and_drop_windows_preserve_replicated_results() {
    let run = |plan: Option<FaultPlan>| {
        let mut h = sim_handles(Variant::LockFree, 4, 2);
        if let Some(p) = plan {
            h[0].set_fault_plan(p);
        }
        let (keys, vals) = keyset();
        let t0 = h[0].sim_time();
        h[0].write_batch(&keys, &vals);
        let got = h[3].read_batch(&keys);
        for (v, g) in vals.iter().zip(got.iter()) {
            assert_eq!(Some(v), g.as_ref());
        }
        (h[0].sim_time() - t0, h[0].fault_stats())
    };
    let (base, _) = run(None);
    let (slow, fs) = run(Some(
        FaultPlan::default()
            .delay_window(1, 0, u64::MAX, 5_000)
            .drop_window(2, 0, u64::MAX, 20_000),
    ));
    assert!(slow > base, "perturbed run is slower ({slow} vs {base})");
    assert!(fs.delayed_msgs > 0 && fs.dropped_msgs > 0);
}

/// Liveness of the degraded write path: a replicated write whose copy
/// lands at a failed rank must *terminate* on every variant — in
/// particular the fine-grained bucket-lock CAS loop must not spin
/// forever against lost memory (vacuous-success CAS, see `rma::fault`).
#[test]
fn writes_at_failed_rank_terminate_all_variants() {
    for variant in Variant::ALL {
        let mut h = Dht::create(variant, 4, 64 * 1024, KEY, VAL);
        for hh in h.iter_mut() {
            hh.set_replicas(2);
        }
        h[0].set_rank_failed(2, true);
        // copies targeting rank 2 are dropped in degraded mode; the
        // batch still completes and primaries land
        let keys: Vec<Vec<u8>> = (0..40u64).map(|i| key_for(i, KEY)).collect();
        let vals: Vec<Vec<u8>> =
            (0..40u64).map(|i| value_for(i, VAL)).collect();
        h[0].write_batch(&keys, &vals);
        let got = h[1].read_batch(&keys);
        let mut hits = 0;
        for (g, v) in got.iter().zip(vals.iter()) {
            if let Some(gv) = g {
                assert_eq!(gv, v, "{variant:?}: never a foreign value");
                hits += 1;
            }
        }
        // every key keeps one live copy: primaries land for keys owned
        // by live ranks, and a key owned by the dead rank has its
        // replica on the next (live) rank — so reads serve everything
        assert!(hits >= 38, "{variant:?}: only {hits}/40 after the kill");
        h[0].set_rank_failed(2, false);
    }
}

/// The shm backend's failed-rank mask provides the same failover surface
/// under real thread concurrency.
#[test]
fn shm_failed_mask_failover_roundtrip() {
    let mut h = Dht::create(Variant::LockFree, 4, 256 * 1024, KEY, VAL);
    for hh in h.iter_mut() {
        hh.set_replicas(2);
    }
    let (keys, vals) = keyset();
    h[1].write_batch(&keys, &vals);
    h[0].set_rank_failed(3, true);
    let got = h[0].read_batch(&keys);
    let mut hits = 0u64;
    for (v, g) in vals.iter().zip(got.iter()) {
        if let Some(gv) = g {
            assert_eq!(gv, v);
            hits += 1;
        }
    }
    assert!(hits >= KEYS - 2, "only {hits}/{KEYS} with a masked rank");
    assert!(h[0].stats().failover_reads > 0);
    // reviving the rank restores the primary path
    h[0].set_rank_failed(3, false);
    let again = h[0].read_batch(&keys);
    assert!(again.iter().filter(|g| g.is_some()).count() as u64 >= KEYS - 2);
}

/// Checkpoint round trip through a replicated cluster: capture
/// de-duplicates the copies, `restore_replicated` fans them back out,
/// and the restored cache tolerates a kill immediately.
#[test]
fn checkpoint_restore_with_replicas_roundtrip() {
    let mut h = Dht::create(Variant::LockFree, 4, 128 * 1024, KEY, VAL);
    for hh in h.iter_mut() {
        hh.set_replicas(2);
    }
    let (keys, vals) = keyset();
    h[0].write_batch(&keys, &vals);
    let cp = DhtCheckpoint::capture(&h);
    assert!(
        cp.entries.len() as u64 >= KEYS - 2,
        "copies de-duplicate to one entry per key ({})",
        cp.entries.len()
    );
    assert!(cp.entries.len() as u64 <= KEYS);
    let bytes = cp.to_bytes();
    let cp2 = DhtCheckpoint::from_bytes(&bytes).expect("v2 parses");
    // different geometry AND replication from step one
    let mut r = cp2.restore_replicated(Variant::LockFree, 3, 256 * 1024, 2);
    r[0].set_rank_failed(1, true);
    let got = r[2].read_batch(&keys);
    let hits = got
        .iter()
        .zip(vals.iter())
        .filter(|(g, v)| g.as_ref() == Some(*v))
        .count() as u64;
    assert!(hits >= KEYS - 4, "only {hits}/{KEYS} after restore + kill");
    assert!(r[2].stats().failover_reads > 0);
}

/// Delegated × replication × rank-kill → repair (DESIGN.md §12 ∘ §11):
/// the delegated data plane rides mailboxes, reads whose primary died
/// fail over to replicas, and the repair scan — control-plane raw RMA,
/// never mailbox traffic — re-homes the lost copies.  The schedule is
/// derived from one pinned seed, so any failure reproduces exactly.
#[test]
fn delegated_replicated_kill_repair_roundtrip() {
    let mut g = mpi_dht::util::prop::G::new(0xDE1E_6A7E);
    let mut h = Dht::create(Variant::Delegated, 4, 256 * 1024, KEY, VAL);
    for hh in h.iter_mut() {
        hh.set_replicas(2);
        hh.set_repair(true);
    }
    let ids: Vec<u64> = (0..KEYS).map(|_| g.u64()).collect();
    let keys: Vec<Vec<u8>> = ids.iter().map(|&i| key_for(i, KEY)).collect();
    let vals: Vec<Vec<u8>> =
        ids.iter().map(|&i| value_for(i.wrapping_mul(3), VAL)).collect();
    h[0].write_batch(&keys, &vals);
    h[0].set_rank_failed(1, true);

    // phase 1 — failover: every key is still served over the mailbox
    // data plane (dead-rank mailboxes answer degraded misses)
    let got = h[2].read_batch(&keys);
    let mut hits = 0u64;
    for (v, gv) in vals.iter().zip(got.iter()) {
        if let Some(gv) = gv {
            assert_eq!(gv, v, "never a foreign value through failover");
            hits += 1;
        }
    }
    assert!(hits >= KEYS - 2, "only {hits}/{KEYS} served after the kill");
    let mut s1 = mpi_dht::dht::DhtStats::default();
    for hh in h.iter_mut() {
        s1.merge(&hh.take_stats());
    }
    assert!(s1.mailbox_ops > 0, "data plane rode the mailboxes");
    assert!(s1.failover_reads > 0, "failover engaged");

    // phase 2 — repair, in isolation: live handles re-walk their shards
    // and re-home the dead rank's copies.  No data-plane ops run here,
    // so the mailbox counters must stay at zero — repair is raw RMA.
    for (r, hh) in h.iter_mut().enumerate() {
        if r != 1 {
            hh.drain_repair();
            assert!(!hh.repairing(), "rank {r}: pass must complete");
        }
    }
    let mut s2 = mpi_dht::dht::DhtStats::default();
    for hh in h.iter_mut() {
        s2.merge(&hh.take_stats());
    }
    assert!(s2.repaired > 0, "lost copies were re-homed");
    assert_eq!(
        s2.mailbox_ops, 0,
        "repair must bypass the mailbox (control plane only)"
    );

    // phase 3 — the healed placement serves every key even with the
    // dead rank still down and failover disabled as a crutch: reads
    // through any surviving handle hit on live copies
    let got = h[3].read_batch(&keys);
    for (i, (v, gv)) in vals.iter().zip(got.iter()).enumerate() {
        assert_eq!(gv.as_ref(), Some(v), "key {i} lost after repair");
    }

    // phase 4 — revive: the rank rejoins with stale-but-valid copies;
    // nothing reads foreign values afterwards
    h[0].set_rank_failed(1, false);
    for hh in h.iter_mut() {
        hh.drain_repair();
    }
    let got = h[1].read_batch(&keys);
    for (v, gv) in vals.iter().zip(got.iter()) {
        if let Some(gv) = gv {
            assert_eq!(gv, v, "revived copies must not serve foreign data");
        }
    }
}

// ------------------------------------------------------------- POET soak

fn chaos_cfg(replicas: u32) -> PoetDesCfg {
    let mut c = PoetDesCfg::scaled(8, Some(Variant::LockFree));
    c.ny = 12;
    c.nx = 24;
    c.steps = 16;
    c.inj_rows = 3;
    c.replicas = replicas;
    c
}

/// The headline chaos soak (acceptance criterion): kill a rank mid-run
/// in the DES POET model with k = 2 — the run completes, reads fail
/// over, the final-window hit rate stays within 5 points of the
/// fault-free run, and the physics still matches the no-DHT baseline.
#[test]
fn poet_kill_with_replication_recovers_hit_rate() {
    let base = chaos_cfg(2);
    let fault_free = run_poet_des(base.clone(), NetConfig::pik_ndr());
    assert!(fault_free.hit_rate() > 0.5, "{}", fault_free.hit_rate());
    let mut chaos = base.clone();
    // kill rank 3 at ~40 % of the fault-free simulated runtime —
    // derived from simulated time, so the schedule is reproducible
    let kill_at = (fault_free.runtime_s * 0.4 * 1e9) as u64;
    chaos.kill_rank_at = Some((3, kill_at));
    let res = run_poet_des(chaos, NetConfig::pik_ndr());
    assert!(
        res.dht.failover_reads > 0,
        "replica failover must have served reads"
    );
    let lo = base.steps * 3 / 4;
    let ff = fault_free.hit_rate_over(lo, base.steps);
    let ch = res.hit_rate_over(lo, base.steps);
    assert!(
        ch + 0.05 >= ff,
        "final-window hit rate {ch:.3} must be within 5 points of the \
         fault-free {ff:.3}"
    );
    // the cache surviving must not corrupt the physics: the final
    // concentrations match the no-DHT reference within §5 tolerance
    let mut refc = PoetDesCfg::scaled(8, None);
    refc.ny = 12;
    refc.nx = 24;
    refc.steps = 16;
    refc.inj_rows = 3;
    let refr = run_poet_des(refc, NetConfig::pik_ndr());
    let d = (res.max_dolomite - refr.max_dolomite).abs();
    assert!(
        d <= 0.35 * refr.max_dolomite.max(1e-12),
        "dolomite {} vs reference {}",
        res.max_dolomite,
        refr.max_dolomite
    );
}

/// A transient drop window shorter than one retry ladder is absorbed:
/// ops pay retries and backoff, but no budget exhausts and the failure
/// detector records ZERO false dead marks (the acceptance criterion for
/// detection robustness, DESIGN.md §11).
#[test]
fn transient_drop_window_absorbed_with_zero_false_deads() {
    let mut h = sim_handles(Variant::LockFree, 4, 2);
    let (keys, vals) = keyset();
    let t0 = h[0].sim_time();
    // all traffic into rank 1 is dropped for 150 µs — well inside the
    // 5-attempt exponential ladder (~620 µs of backoff headroom)
    h[0].set_fault_plan(
        FaultPlan::default().drop_window(1, t0, t0 + 150_000, 20_000),
    );
    h[0].write_batch(&keys, &vals);
    let got = h[3].read_batch(&keys);
    for (v, g) in vals.iter().zip(got.iter()) {
        assert_eq!(Some(v), g.as_ref(), "nothing lost to the window");
    }
    let fs = h[0].fault_stats();
    assert!(fs.dropped_msgs > 0, "the window did bite");
    assert!(fs.retries > 0, "dropped messages were retried");
    assert_eq!(fs.exhausted_msgs, 0, "no retry budget exhausted");
    let s = h[0].take_stats();
    assert!(s.retries > 0, "retry cost surfaced in DhtStats");
    assert!(s.backoff_ns > 0, "backoff cost surfaced in DhtStats");
    assert_eq!(s.ranks_dead, 0, "zero false dead marks");
}

/// The tentpole headline (ISSUE acceptance): kill a rank mid-POET-run
/// with k = 2 AND online repair — surviving ranks re-home the lost
/// copies piggybacked on normal traffic, and the final-window hit rate
/// comes back to within 2 points of the fault-free run.
#[test]
fn poet_kill_with_repair_restores_hit_rate_within_two_points() {
    let mut base = chaos_cfg(2);
    base.repair = true;
    base.pipeline = 4;
    // ~1.3k lock-free buckets/rank: a full repair scan finishes well
    // inside the post-kill tail of the run
    base.win_bytes = 256 * 1024;
    let fault_free = run_poet_des(base.clone(), NetConfig::pik_ndr());
    assert!(fault_free.hit_rate() > 0.5, "{}", fault_free.hit_rate());
    assert_eq!(fault_free.dht.ranks_dead, 0, "fault-free stays clean");
    let mut chaos = base.clone();
    let kill_at = (fault_free.runtime_s * 0.4 * 1e9) as u64;
    chaos.kill_rank_at = Some((3, kill_at));
    let res = run_poet_des(chaos, NetConfig::pik_ndr());
    // detection fed by op outcomes, not an oracle
    assert!(res.sim.faults.exhausted_msgs > 0, "budgets exhausted");
    assert!(res.dht.retries > 0, "retry cost in DhtStats");
    assert_eq!(res.dht.ranks_dead, 1, "the kill is held at exit");
    // online repair re-homed the surviving copies
    assert!(res.dht.repaired > 0, "repair pushed lost copies");
    let lo = base.steps * 3 / 4;
    let ff = fault_free.hit_rate_over(lo, base.steps);
    let ch = res.hit_rate_over(lo, base.steps);
    assert!(
        ch + 0.02 >= ff,
        "final-window hit rate {ch:.3} must be within 2 points of the \
         fault-free {ff:.3}"
    );
}

/// Full self-healing cycle: kill -> detect -> repair -> revive.  The
/// revived rank is re-discovered by a liveness probe, the detector ends
/// the run with zero dead ranks, and the physics stays correct.
#[test]
fn poet_kill_repair_revive_soak() {
    let mut base = chaos_cfg(2);
    base.repair = true;
    base.pipeline = 4;
    base.win_bytes = 256 * 1024;
    let fault_free = run_poet_des(base.clone(), NetConfig::pik_ndr());
    let mut chaos = base.clone();
    chaos.kill_rank_at =
        Some((3, (fault_free.runtime_s * 0.3 * 1e9) as u64));
    chaos.revive_rank_at =
        Some((3, (fault_free.runtime_s * 0.6 * 1e9) as u64));
    let res = run_poet_des(chaos, NetConfig::pik_ndr());
    assert!(res.dht.repaired > 0, "repair ran while the rank was down");
    assert_eq!(
        res.dht.ranks_dead, 0,
        "a probe must have revived the rank before the run ended"
    );
    assert!(res.hit_rate() > 0.4, "hit rate {}", res.hit_rate());
    // the healed cache must not corrupt the physics
    let mut refc = PoetDesCfg::scaled(8, None);
    refc.ny = 12;
    refc.nx = 24;
    refc.steps = 16;
    refc.inj_rows = 3;
    let refr = run_poet_des(refc, NetConfig::pik_ndr());
    let d = (res.max_dolomite - refr.max_dolomite).abs();
    assert!(
        d <= 0.35 * refr.max_dolomite.max(1e-12),
        "dolomite {} vs reference {}",
        res.max_dolomite,
        refr.max_dolomite
    );
}

/// Chaos × multi-tenancy (DESIGN.md §14 ∘ §11): kill a rank and repair
/// it back while TWO phase-shifted tenants drive the same replicated
/// cache under second-chance eviction.  The repair scan re-homes
/// records *with their tenant/age meta word intact*, so after the heal
/// both tenants keep hitting their own namespaces, the per-tenant
/// ledgers still reconcile against the global counters, and the
/// fairness index stays meaningful.
#[test]
fn poet_two_tenant_kill_repair_keeps_ledgers_and_fairness() {
    let mut base = chaos_cfg(2);
    base.repair = true;
    base.pipeline = 4;
    base.win_bytes = 256 * 1024;
    base.tenants = 2;
    base.evict = EvictPolicy::SecondChance;
    base.tenant_phase = 2; // tenant 1 joins at step 2, active for ~all
    let fault_free = run_poet_des(base.clone(), NetConfig::pik_ndr());
    assert!(fault_free.hit_rate() > 0.4, "{}", fault_free.hit_rate());
    let mut chaos = base.clone();
    chaos.kill_rank_at =
        Some((3, (fault_free.runtime_s * 0.3 * 1e9) as u64));
    chaos.revive_rank_at =
        Some((3, (fault_free.runtime_s * 0.6 * 1e9) as u64));
    let res = run_poet_des(chaos, NetConfig::pik_ndr());
    // the self-healing cycle ran end to end under multi-tenant load
    assert!(res.dht.repaired > 0, "repair re-homed lost copies");
    assert_eq!(res.dht.ranks_dead, 0, "the revived rank was re-found");
    // both tenants lived through the kill: each namespace records
    // lookups AND hits after failover + repair
    assert_eq!(res.tenant_hits.len(), 2);
    for (t, &(h, m)) in res.tenant_hits.iter().enumerate() {
        assert!(h + m > 0, "tenant {t} issued no lookups");
        assert!(h > 0, "tenant {t} never hit after the chaos cycle");
    }
    // ledger conservation: the per-tenant (hits, misses) split is
    // exactly the global count
    let (th, tm): (u64, u64) = res
        .tenant_hits
        .iter()
        .fold((0, 0), |(a, b), &(h, m)| (a + h, b + m));
    assert_eq!(th, res.hits, "tenant hit ledgers must sum to the total");
    assert_eq!(tm, res.misses, "and tenant misses to the global misses");
    let f = res.fairness();
    assert!(f > 0.0 && f <= 1.0, "fairness {f} out of range");
    // the healed, namespaced cache must not corrupt the physics
    let mut refc = PoetDesCfg::scaled(8, None);
    refc.ny = 12;
    refc.nx = 24;
    refc.steps = 16;
    refc.inj_rows = 3;
    let refr = run_poet_des(refc, NetConfig::pik_ndr());
    let d = (res.max_dolomite - refr.max_dolomite).abs();
    assert!(
        d <= 0.35 * refr.max_dolomite.max(1e-12),
        "dolomite {} vs reference {}",
        res.max_dolomite,
        refr.max_dolomite
    );
}

/// The same kill without replication: the run still completes with
/// correct physics, but the lost shard costs misses for the rest of the
/// run — the gap replication closes.
#[test]
fn poet_kill_without_replication_degrades() {
    let base = chaos_cfg(1);
    let fault_free = run_poet_des(base.clone(), NetConfig::pik_ndr());
    let mut chaos = base.clone();
    chaos.kill_rank_at =
        Some((3, (fault_free.runtime_s * 0.4 * 1e9) as u64));
    let res = run_poet_des(chaos, NetConfig::pik_ndr());
    assert!(res.max_dolomite > 0.0, "the run completed with physics");
    assert_eq!(res.dht.failover_reads, 0, "k = 1 has nowhere to fail over");
    assert!(
        res.misses > fault_free.misses,
        "the lost shard must cost misses ({} vs {})",
        res.misses,
        fault_free.misses
    );
    let lo = base.steps * 3 / 4;
    assert!(
        res.hit_rate_over(lo, base.steps)
            < fault_free.hit_rate_over(lo, base.steps),
        "unreplicated hit rate must stay degraded"
    );
}
