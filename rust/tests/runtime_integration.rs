//! Layer contract: the Rust PJRT runtime must reproduce, bit-for-fp-bit,
//! the golden vectors computed by the Python kernels at AOT time.  This is
//! the test that proves L1/L2 (Pallas/JAX) and L3 (Rust) agree.
//!
//! Requires `make artifacts` to have run (skips otherwise).

use mpi_dht::runtime::Engine;

fn engine() -> Option<Engine> {
    if !Engine::available() {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    let dir = Engine::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::load(dir).expect("engine load"))
}

#[test]
fn chemistry_matches_golden() {
    let Some(e) = engine() else { return };
    let g = e.manifest().golden_chemistry().expect("golden");
    let out = e.chemistry(&g.inputs, g.rows).expect("chemistry exec");
    assert_eq!(out.len(), g.expect.len());
    for (i, (a, b)) in out.iter().zip(g.expect.iter()).enumerate() {
        let tol = 1e-12 * b.abs().max(1e-30) + 1e-15;
        assert!(
            (a - b).abs() <= tol,
            "golden mismatch at {i}: {a} vs {b}"
        );
    }
}

#[test]
fn transport_matches_golden() {
    let Some(e) = engine() else { return };
    let g = e.manifest().golden_transport().expect("golden");
    let out = e
        .transport(g.ny, g.nx, &g.c, &g.inflow, g.cf, g.inj_rows)
        .expect("transport exec");
    assert_eq!(out.len(), g.expect.len());
    for (i, (a, b)) in out.iter().zip(g.expect.iter()).enumerate() {
        assert!(
            (a - b).abs() <= 1e-14,
            "golden mismatch at {i}: {a} vs {b}"
        );
    }
}

#[test]
fn chemistry_padding_and_splitting() {
    let Some(e) = engine() else { return };
    let g = e.manifest().golden_chemistry().expect("golden");
    let n_in = e.manifest().n_in;
    let n_out = e.manifest().n_out;
    // build an odd-sized batch (not matching any lowered size) by tiling
    // the golden inputs 7x, then check row-by-row against tiled outputs
    let reps = 7;
    let mut rows = Vec::new();
    for _ in 0..reps {
        rows.extend_from_slice(&g.inputs);
    }
    let n = g.rows * reps;
    assert_eq!(rows.len(), n * n_in);
    let out = e.chemistry(&rows, n).expect("chemistry exec");
    assert_eq!(out.len(), n * n_out);
    for r in 0..n {
        let gr = r % g.rows;
        for c in 0..n_out {
            let a = out[r * n_out + c];
            let b = g.expect[gr * n_out + c];
            let tol = 1e-12 * b.abs().max(1e-30) + 1e-15;
            assert!((a - b).abs() <= tol, "row {r} col {c}: {a} vs {b}");
        }
    }
}

#[test]
fn chemistry_batch_selection() {
    let Some(e) = engine() else { return };
    // smallest batch >= n
    let b1 = e.chemistry_batch_for(1).unwrap();
    let b33 = e.chemistry_batch_for(33).unwrap();
    assert!(b1 >= 1);
    assert!(b33 >= 33);
    assert!(b1 <= b33);
    // huge n falls back to the largest lowered size
    let huge = e.chemistry_batch_for(1_000_000).unwrap();
    assert!(huge >= b33);
}

#[test]
fn transport_is_stationary_for_background_inflow() {
    let Some(e) = engine() else { return };
    let m = e.manifest().clone();
    let t = &m.transport[0];
    let ns = m.n_solutes;
    // uniform background grid with background inflow: advection is a no-op
    let mut c = Vec::with_capacity(ns * t.ny * t.nx);
    for s in 0..ns {
        c.extend(std::iter::repeat(m.background[s]).take(t.ny * t.nx));
    }
    let mut inflow = Vec::with_capacity(ns * 2);
    for s in 0..ns {
        inflow.push(m.background[s]); // injection == background here
        inflow.push(m.background[s]);
    }
    let out = e
        .transport(t.ny, t.nx, &c, &inflow, [0.3, 0.1], 3)
        .expect("transport exec");
    for (a, b) in out.iter().zip(c.iter()) {
        assert!((a - b).abs() < 1e-15);
    }
}

#[test]
fn engine_warm_up_compiles_all() {
    let Some(e) = engine() else { return };
    e.warm_up().expect("warm up");
}
