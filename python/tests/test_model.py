"""L2 integration: coupled transport + chemistry reproduces the paper's
reaction-front narrative (§5.4): MgCl2 injection -> calcite dissolves and
dolomite precipitates at the front; behind the front, once calcite is
consumed, dolomite redissolves.  Also checks the cache-friendliness property
the whole surrogate approach rests on: cells away from the front do not
change between steps.
"""

import numpy as np

import jax.numpy as jnp

from compile import model


def run_coupled(ny=16, nx=48, steps=60, dt=2000.0, inj_rows=6,
                cf=(0.4, 0.0)):
    """Minimal python mirror of the Rust POET driver."""
    c = np.asarray(model.initial_grid(ny, nx))
    minerals = np.empty((2, ny, nx))
    minerals[0] = model.MINERALS0[0]
    minerals[1] = model.MINERALS0[1]
    inflow = jnp.asarray(model.default_inflow())
    cfj = jnp.asarray(cf)
    inj = jnp.asarray([inj_rows], dtype=jnp.int32)

    for _ in range(steps):
        c = np.asarray(model.transport_step(jnp.asarray(c), inflow, cfj, inj))
        batch = np.concatenate(
            [c.reshape(model.N_SOLUTES, -1).T,
             minerals.reshape(2, -1).T,
             np.full((ny * nx, 1), dt)], axis=1)
        out = np.asarray(model.chemistry_step(jnp.asarray(batch)))
        c = out[:, :model.N_SOLUTES].T.reshape(model.N_SOLUTES, ny, nx)
        minerals = out[:, 7:9].T.reshape(2, ny, nx)
    return c, minerals


def test_front_narrative():
    c, minerals = run_coupled()
    calcite, dolomite = minerals
    # near the inlet (injection rows, first columns) calcite was consumed
    inlet = calcite[:4, :4]
    assert inlet.mean() < 0.5 * model.MINERALS0[0]
    # dolomite appeared somewhere along the flow path
    assert dolomite.max() > 1e-6
    # far downstream, untouched: calcite at initial value, no dolomite
    far = calcite[:, -8:]
    np.testing.assert_allclose(far, model.MINERALS0[0], rtol=1e-6)
    np.testing.assert_allclose(dolomite[:, -8:], 0.0, atol=1e-12)
    # rows below the injection stream stay pristine
    np.testing.assert_allclose(calcite[10:, :], model.MINERALS0[0], rtol=1e-6)


def test_unreached_cells_are_stationary():
    """The surrogate-cache premise: away from the front, chemistry outputs
    repeat exactly, so rounded keys repeat and the DHT hit rate is high."""
    ny, nx = 8, 32
    c = np.asarray(model.initial_grid(ny, nx))
    minerals = np.broadcast_to(
        np.asarray(model.MINERALS0)[:, None, None], (2, ny, nx)).copy()
    batch = np.concatenate(
        [c.reshape(model.N_SOLUTES, -1).T, minerals.reshape(2, -1).T,
         np.full((ny * nx, 1), 2000.0)], axis=1)
    out1 = np.asarray(model.chemistry_step(jnp.asarray(batch)))
    batch2 = np.concatenate(
        [out1[:, :7], out1[:, 7:9], np.full((ny * nx, 1), 2000.0)], axis=1)
    out2 = np.asarray(model.chemistry_step(jnp.asarray(batch2)))
    # background water equilibrates quickly: successive outputs converge
    d = np.abs(out2[:, :9] - out1[:, :9]).max()
    assert d < 1e-5
    # and identical inputs give identical outputs (key-repeat determinism)
    out1b = np.asarray(model.chemistry_step(jnp.asarray(batch)))
    np.testing.assert_array_equal(out1, out1b)


def test_solutes_positive_and_finite():
    c, minerals = run_coupled(steps=30)
    assert np.isfinite(c).all() and np.isfinite(minerals).all()
    assert (c[:4] > 0).all()          # concentrations stay positive
    assert (minerals >= 0).all()


def test_longer_run_redissolves_dolomite():
    """Dolomite is transient: it precipitates at the moving front and
    redissolves behind it once calcite is exhausted (paper §5.4)."""
    _, m_mid = run_coupled(steps=120, dt=2000.0)
    _, m_late = run_coupled(steps=400, dt=2000.0)
    assert m_mid[1].max() > 1e-5               # dolomite present mid-run
    assert m_late[1].max() < 0.5 * m_mid[1].max()  # later redissolved
    assert m_late[0][:3, :2].mean() < 1e-6     # calcite gone at inlet
