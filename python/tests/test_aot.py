"""Build-path tests: aot.py lowering, manifest and golden vectors.

The golden files written here are exactly what the Rust runtime integration
tests replay through PJRT, so this test pins the contract between layers.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(out),
                "--chem-batches", "32,128", "--grids", "16x32"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    return out


def read_manifest(out):
    entries = []
    with open(os.path.join(out, "manifest.txt")) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            kind, rest = line.split(" ", 1)
            kv = {}
            for tok in rest.split(" "):
                if "=" in tok:
                    k, v = tok.split("=", 1)
                    kv[k] = v
            entries.append((kind, kv, rest))
    return entries


def test_manifest_lists_all_artifacts(artifacts):
    entries = read_manifest(artifacts)
    kinds = [k for k, _, _ in entries]
    assert kinds.count("chemistry") == 2
    assert kinds.count("transport") == 1
    assert kinds.count("golden") == 2
    assert "constants" in kinds
    assert kinds.count("water") == 3
    for kind, kv, _ in entries:
        if "file" in kv:
            assert os.path.exists(os.path.join(artifacts, kv["file"])), kv


def test_hlo_text_is_loadable_format(artifacts):
    """HLO text header sanity + no Mosaic custom-calls (CPU-executable)."""
    for name in os.listdir(artifacts):
        if not name.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(artifacts, name)).read()
        assert text.startswith("HloModule"), name
        assert "custom-call" not in text, name
        assert "ENTRY" in text, name


def test_constants_match_model(artifacts):
    entries = read_manifest(artifacts)
    consts = next(kv for k, kv, _ in entries if k == "constants")
    assert int(consts["n_solutes"]) == model.N_SOLUTES
    assert int(consts["n_species"]) == model.N_SPECIES
    assert int(consts["n_in"]) == model.N_IN
    assert int(consts["n_out"]) == model.N_OUT


def test_golden_chemistry_reproduces(artifacts):
    path = os.path.join(artifacts, "golden_chemistry.txt")
    with open(path) as f:
        rows, nin, nout = (int(v) for v in f.readline().split())
        data = [np.fromstring(f.readline(), sep=" ") for _ in range(2 * rows)]
    inp = np.stack(data[:rows])
    expect = np.stack(data[rows:])
    assert inp.shape == (rows, nin) and expect.shape == (rows, nout)
    got = np.asarray(model.chemistry_step(jnp.asarray(inp)))
    np.testing.assert_allclose(got, expect, atol=1e-15, rtol=1e-12)


def test_golden_transport_reproduces(artifacts):
    path = os.path.join(artifacts, "golden_transport.txt")
    with open(path) as f:
        ns, ny, nx, inj_rows = (int(v) for v in f.readline().split())
        fields = {}
        for line in f:
            name, rest = line.split(" ", 1)
            fields[name] = np.fromstring(rest, sep=" ")
    c = fields["c"].reshape(ns, ny, nx)
    inflow = fields["inflow"].reshape(ns, 2)
    cf = fields["cf"]
    expect = fields["out"].reshape(ns, ny, nx)
    got = np.asarray(model.transport_step(
        jnp.asarray(c), jnp.asarray(inflow), jnp.asarray(cf),
        jnp.asarray([inj_rows], dtype=jnp.int32)))
    np.testing.assert_allclose(got, expect, atol=1e-15, rtol=1e-12)


def test_repo_artifacts_fresh_if_present():
    """If the repo-level artifacts/ dir exists, its manifest must parse."""
    repo_artifacts = os.path.join(os.path.dirname(__file__), "..", "..",
                                  "artifacts")
    if not os.path.exists(os.path.join(repo_artifacts, "manifest.txt")):
        pytest.skip("repo artifacts not built")
    entries = read_manifest(repo_artifacts)
    assert any(k == "chemistry" for k, _, _ in entries)
    assert any(k == "transport" for k, _, _ in entries)
