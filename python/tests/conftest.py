"""Shared fixtures and strategies for the python test suite."""

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)


def make_chem_batch(rng: np.random.Generator, rows: int) -> np.ndarray:
    """Random but physically-plausible chemistry input batch f64[rows, 10]."""
    b = np.empty((rows, 10))
    b[:, 0] = rng.uniform(1e-6, 1e-3, rows)   # Ca
    b[:, 1] = rng.uniform(1e-6, 1e-3, rows)   # Mg
    b[:, 2] = rng.uniform(1e-5, 2e-3, rows)   # C
    b[:, 3] = rng.uniform(1e-6, 2e-3, rows)   # Cl
    b[:, 4] = rng.uniform(5.0, 10.0, rows)    # pH
    b[:, 5] = rng.uniform(-4.0, 12.0, rows)   # pe (inert)
    b[:, 6] = rng.uniform(0.0, 5e-4, rows)    # O0 (inert)
    b[:, 7] = rng.uniform(0.0, 4e-4, rows)    # Calcite
    b[:, 8] = rng.uniform(0.0, 2e-4, rows)    # Dolomite
    b[:, 9] = rng.uniform(0.0, 500.0, rows)   # dt
    return b


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
