"""L1 correctness: the Pallas upwind advection kernel vs the pure-jnp oracle.

Hypothesis sweeps grid shapes and dtypes; invariant tests pin the physics
POET relies on (boundedness, inflow boundaries, zero-CFL identity).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import advection, ref


def run_both(c, inflow, cf, inj_rows, dtype=np.float64):
    c = np.asarray(c, dtype=dtype)
    inflow = np.asarray(inflow, dtype=dtype)
    out_k = np.asarray(advection.advect_step(
        jnp.asarray(c), jnp.asarray(inflow), jnp.asarray(cf, dtype=dtype),
        jnp.asarray([inj_rows], dtype=jnp.int32)))
    out_r = np.asarray(ref.advect_step_ref(c, inflow, cf, inj_rows))
    return out_k, out_r


def random_setup(rng, ns, ny, nx):
    c = rng.uniform(0.0, 1e-3, size=(ns, ny, nx))
    inflow = rng.uniform(0.0, 1e-3, size=(ns, 2))
    return c, inflow


# ---------------------------------------------------------------------------
# kernel vs oracle across shapes / dtypes
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    ns=st.integers(1, 8),
    ny=st.one_of(st.integers(1, 20), st.sampled_from([16, 32, 48, 64])),
    nx=st.integers(2, 40),
    cfx=st.floats(0.0, 0.6),
    cfy=st.floats(0.0, 0.4),
    inj_frac=st.floats(0.0, 1.0),
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref(ns, ny, nx, cfx, cfy, inj_frac, dtype, seed):
    rng = np.random.default_rng(seed)
    c, inflow = random_setup(rng, ns, ny, nx)
    inj_rows = int(inj_frac * ny)
    out_k, out_r = run_both(c, inflow, [cfx, cfy], inj_rows, dtype)
    atol = 1e-14 if dtype is np.float64 else 1e-6
    np.testing.assert_allclose(out_k, out_r, atol=atol)
    assert out_k.dtype == dtype


def test_row_block_boundary(rng):
    """ny that is an exact multiple of ROW_BLOCK exercises the halo path."""
    ny = 3 * advection.ROW_BLOCK
    c, inflow = random_setup(rng, 4, ny, 24)
    out_k, out_r = run_both(c, inflow, [0.3, 0.2], 5)
    np.testing.assert_allclose(out_k, out_r, atol=1e-14)


# ---------------------------------------------------------------------------
# physics invariants
# ---------------------------------------------------------------------------

def test_zero_cfl_is_identity(rng):
    c, inflow = random_setup(rng, 3, 16, 16)
    out_k, _ = run_both(c, inflow, [0.0, 0.0], 4)
    np.testing.assert_array_equal(out_k, c)


def test_uniform_field_with_matching_inflow_is_stationary():
    """c == inflow everywhere -> nothing changes (steady state)."""
    ns, ny, nx = 4, 16, 24
    vals = np.linspace(0.1, 0.4, ns)
    c = np.broadcast_to(vals[:, None, None], (ns, ny, nx)).copy()
    inflow = np.stack([vals, vals], axis=1)
    out_k, _ = run_both(c, inflow, [0.3, 0.1], 0)
    np.testing.assert_allclose(out_k, c, atol=1e-15)


def test_upwind_monotone_bounds(rng):
    """First-order upwind under CFL is monotone: no new extrema appear."""
    c, inflow = random_setup(rng, 2, 32, 32)
    cf = [0.5, 0.3]
    out_k, _ = run_both(c, inflow, cf, 8)
    lo = min(c.min(), inflow.min())
    hi = max(c.max(), inflow.max())
    assert out_k.min() >= lo - 1e-15
    assert out_k.max() <= hi + 1e-15


def test_injection_enters_top_left_only():
    """Plume from the injection rows: only those rows see injection water."""
    ns, ny, nx = model.N_SOLUTES, 16, 32
    c = np.asarray(model.initial_grid(ny, nx))
    inflow = np.asarray(model.default_inflow())
    inj_rows = 4
    out = c
    for _ in range(5):
        out, _ = run_both(out, inflow, [0.4, 0.0], inj_rows)
    mg = out[1]  # Mg plane: injected species
    bg_mg = model.BACKGROUND[1]
    assert (mg[:inj_rows, 0] > 10 * bg_mg).all()   # plume present
    assert np.allclose(mg[inj_rows:, :], bg_mg)    # below: background only


def test_transport_advances_front(rng):
    """After k steps with cfy=0, the front reaches ~ k*cfx columns."""
    ns, ny, nx = 1, 8, 64
    c = np.full((ns, ny, nx), 1e-6)
    inflow = np.array([[1e-3, 1e-6]])
    steps, cfx = 40, 0.5
    out = c
    for _ in range(steps):
        out, _ = run_both(out, inflow, [cfx, 0.0], ny)
    # columns well behind the front are saturated, far ahead untouched
    assert (out[0, :, :5] > 5e-4).all()
    assert np.allclose(out[0, :, 40:], 1e-6, rtol=1e-3)


def test_minerals_not_advected_by_design():
    """Transport takes only solute planes: shape contract with the model."""
    assert model.N_SOLUTES == 7
    assert model.N_SPECIES == 9
