"""L1 correctness: the Pallas chemistry kernel vs the pure-jnp oracle.

This is the build-time correctness gate for the compute hot-spot: the kernel
must agree with ``ref.chemistry_step_ref`` across batch shapes and state
regimes (hypothesis-driven), and must satisfy the physical invariants the
POET coupling relies on (mineral non-negativity, conservative species
untouched, stoichiometric mass balance).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import chemistry as chem
from compile.kernels import ref

from .conftest import make_chem_batch

ATOL, RTOL = 1e-12, 1e-9


def run_both(batch):
    out_k = np.asarray(model.chemistry_step(jnp.asarray(batch)))
    out_r = np.asarray(ref.chemistry_step_ref(batch))
    return out_k, out_r


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    rows=st.one_of(
        st.integers(1, 64),                      # single-tile path
        st.sampled_from([128, 256, 384, 512]),   # tiled path (multiples of 128)
    ),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_shapes(rows, seed):
    rng = np.random.default_rng(seed)
    out_k, out_r = run_both(make_chem_batch(rng, rows))
    np.testing.assert_allclose(out_k, out_r, atol=ATOL, rtol=RTOL)


@settings(max_examples=20, deadline=None)
@given(
    ca=st.floats(1e-9, 1e-2), mg=st.floats(1e-9, 1e-2),
    c=st.floats(1e-9, 1e-2), ph=st.floats(4.0, 11.0),
    calcite=st.floats(0.0, 1e-3), dolomite=st.floats(0.0, 1e-3),
    dt=st.floats(0.0, 1e4),
)
def test_kernel_matches_ref_pointwise(ca, mg, c, ph, calcite, dolomite, dt):
    row = np.array([[ca, mg, c, 1e-5, ph, 4.0, 2.5e-4, calcite, dolomite, dt]])
    out_k, out_r = run_both(row)
    np.testing.assert_allclose(out_k, out_r, atol=ATOL, rtol=RTOL)


def test_tile_boundary_exact_multiple(rng):
    """Batch == k * TILE_B exercises the multi-program grid path."""
    batch = make_chem_batch(rng, 2 * chem.TILE_B)
    out_k, out_r = run_both(batch)
    np.testing.assert_allclose(out_k, out_r, atol=ATOL, rtol=RTOL)
    # tile independence: same rows in a different tile give same results
    out2 = np.asarray(model.chemistry_step(jnp.asarray(batch[::-1].copy())))
    np.testing.assert_allclose(out2[::-1], out_k, atol=ATOL, rtol=RTOL)


# ---------------------------------------------------------------------------
# physical invariants
# ---------------------------------------------------------------------------

def test_conservative_species_untouched(rng):
    batch = make_chem_batch(rng, 64)
    out, _ = run_both(batch)
    np.testing.assert_array_equal(out[:, 3], batch[:, 3])  # Cl
    np.testing.assert_array_equal(out[:, 5], batch[:, 5])  # pe
    np.testing.assert_array_equal(out[:, 6], batch[:, 6])  # O0


def test_minerals_never_negative(rng):
    batch = make_chem_batch(rng, 256)
    batch[:, 9] = 1e4  # aggressive dt
    out, _ = run_both(batch)
    assert (out[:, 7] >= 0.0).all()
    assert (out[:, 8] >= 0.0).all()
    assert (out[:, :3] > 0.0).all()  # solutes stay positive


def test_dt_zero_is_identity(rng):
    batch = make_chem_batch(rng, 32)
    batch[:, 9] = 0.0
    out, _ = run_both(batch)
    np.testing.assert_allclose(out[:, :9], batch[:, :9], atol=1e-15)


def test_calcium_mass_balance(rng):
    """dCa = -dCalcite - dDolomite; dMg = -dDolomite (stoichiometry)."""
    batch = make_chem_batch(rng, 128)
    batch[:, 9] = 100.0
    out, _ = run_both(batch)
    d_cal = batch[:, 7] - out[:, 7]
    d_dol = batch[:, 8] - out[:, 8]
    d_ca = out[:, 0] - batch[:, 0]
    d_mg = out[:, 1] - batch[:, 1]
    d_c = out[:, 2] - batch[:, 2]
    # floors (STATE_MIN clamps) only bind for pathological inputs; these
    # batches stay in the smooth regime.
    np.testing.assert_allclose(d_ca, d_cal + d_dol, atol=1e-12)
    np.testing.assert_allclose(d_mg, d_dol, atol=1e-12)
    np.testing.assert_allclose(d_c, d_cal + 2.0 * d_dol, atol=1e-12)


def test_undersaturated_water_dissolves_calcite():
    """Dilute acidic water + calcite -> dissolution (Ca rises, calcite falls)."""
    row = np.array([[1e-6, 1e-6, 1e-4, 1e-5, 6.0, 4.0, 2.5e-4, 2e-4, 0.0, 500.0]])
    out, _ = run_both(row)
    assert out[0, 0] > row[0, 0]          # Ca released
    assert out[0, 7] < row[0, 7]          # calcite consumed
    # either still dissolving, or the mineral was fully consumed this step
    assert out[0, 9] > 0.0 or out[0, 7] == 0.0
    assert out[0, 11] < 1.0 + 1e-9        # still at/below saturation


def test_mg_rich_water_precipitates_dolomite():
    """The paper's scenario: MgCl2 water over calcite -> dolomite grows."""
    row = np.array([[5e-4, 1e-3, 1e-3, 2e-3, 8.5, 4.0, 2.5e-4, 2e-4, 0.0, 500.0]])
    out, _ = run_both(row)
    assert out[0, 8] > 0.0                # dolomite precipitated
    assert out[0, 10] < 0.0 or out[0, 8] > row[0, 8]


def test_exhausted_minerals_stop_dissolving():
    row = np.array([[1e-6, 1e-6, 1e-4, 1e-5, 6.0, 4.0, 2.5e-4, 0.0, 0.0, 1e4]])
    out, _ = run_both(row)
    np.testing.assert_allclose(out[0, 7], 0.0, atol=1e-18)
    np.testing.assert_allclose(out[0, 8], 0.0, atol=1e-18)
    # with no mineral there is no source: Ca unchanged
    np.testing.assert_allclose(out[0, 0], row[0, 0], rtol=1e-9)


def test_equilibrium_water_is_stationary():
    """Water exactly at calcite saturation with no dolomite driving force."""
    # construct: pick pH/C, solve Ca so omega_cal == 1, Mg tiny
    ph, c = 8.0, 1e-3
    h = 10.0 ** -ph
    a_co3 = c * (chem.K1 * chem.K2) / (h * h + chem.K1 * h + chem.K1 * chem.K2)
    ca = chem.KSP_CAL / a_co3
    row = np.array([[ca, 1e-12, c, 1e-5, ph, 4.0, 2.5e-4, 2e-4, 0.0, 100.0]])
    out, _ = run_both(row)
    np.testing.assert_allclose(out[0, 0], ca, rtol=1e-6)
    np.testing.assert_allclose(out[0, 7], 2e-4, rtol=1e-6)


def test_omega_capped(rng):
    batch = make_chem_batch(rng, 16)
    batch[:, 0] = 1.0   # absurdly supersaturated
    batch[:, 1] = 1.0
    batch[:, 2] = 1.0
    batch[:, 4] = 11.0
    out, _ = run_both(batch)
    assert (out[:, 11] <= chem.OMEGA_CAP).all()
    assert (out[:, 12] <= chem.OMEGA_CAP).all()
    assert np.isfinite(out).all()


def test_determinism(rng):
    batch = make_chem_batch(rng, 128)
    a = np.asarray(model.chemistry_step(jnp.asarray(batch)))
    b = np.asarray(model.chemistry_step(jnp.asarray(batch)))
    np.testing.assert_array_equal(a, b)
