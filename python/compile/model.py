"""L2: the POET compute graph in JAX, calling the L1 Pallas kernels.

Two jittable entry points are AOT-lowered by ``aot.py`` and executed from the
Rust coordinator via PJRT (Python is never on the request path):

* ``chemistry_step``  — batched kinetic calcite/dolomite geochemistry (the
  PHREEQC stand-in; the expensive call the DHT surrogate caches).
* ``transport_step``  — upwind advection of the solute planes.

The species layout and the 80-byte-key / 104-byte-value record structure are
documented in ``kernels/chemistry.py`` and DESIGN.md.
"""

import jax
import jax.numpy as jnp

from .kernels import advection, chemistry

jax.config.update("jax_enable_x64", True)

#: number of solute species that advect (Ca, Mg, C, Cl, pH, pe, O0)
N_SOLUTES = 7
#: full state vector width (solutes + Calcite + Dolomite)
N_SPECIES = chemistry.NSPECIES
#: chemistry input / output record widths (match the paper's 80 B / 104 B)
N_IN = chemistry.NIN
N_OUT = chemistry.NOUT

# Default waters for the paper's scenario: background water equilibrated
# with calcite; injection water = MgCl2 solution (high Mg, high Cl, no Ca).
# The background Ca is computed to sit *exactly* on calcite saturation
# (omega_cal == 1), so cells not yet reached by the injection front are
# chemically stationary — the property the paper's surrogate cache exploits
# ("cells not yet reached by the reactive solution remain unchanged").


def _calcite_equilibrium_ca(ph: float, c: float) -> float:
    h = 10.0 ** (-ph)
    denom = h * h + chemistry.K1 * h + chemistry.K1 * chemistry.K2
    a_co3 = c * (chemistry.K1 * chemistry.K2) / denom
    return chemistry.KSP_CAL / a_co3


_BG_PH, _BG_C = 8.0, 1.0e-3
#               Ca                                Mg      C      Cl      pH      pe   O0
BACKGROUND = [_calcite_equilibrium_ca(_BG_PH, _BG_C),
              1.0e-6, _BG_C, 1.0e-5, _BG_PH, 4.0, 2.5e-4]
# Injected MgCl2 brine: Mg-rich, Ca-free, same carbonate/pH background so
# the front dynamics are Mg-driven exactly as in the paper: rising Mg
# supersaturates dolomite, its precipitation consumes Ca/CO3, which
# undersaturates calcite and dissolves it; once calcite is exhausted the
# Ca supply stops and dolomite redissolves.
INJECTION = [1.0e-6, 2.0e-3, _BG_C, 4.0e-3, _BG_PH, 4.0, 2.5e-4]
#: initial mineral amounts [mol/L medium]: calcite present, no dolomite
MINERALS0 = [2.0e-4, 0.0]


def chemistry_step(batch):
    """Kinetic chemistry over a batch of cells: f64[B, 10] -> f64[B, 13]."""
    return chemistry.chemistry_step(batch)


def transport_step(c, inflow, cf, inj_rows):
    """Upwind-advect the solute planes one step.

    c: f64[N_SOLUTES, ny, nx]; inflow: f64[N_SOLUTES, 2] ([injection,
    background] per species); cf: f64[2]; inj_rows: i32[1].
    """
    return advection.advect_step(c, inflow, cf, inj_rows)


def default_inflow():
    """Per-species [injection, background] inflow table, f64[N_SOLUTES, 2]."""
    return jnp.stack(
        [jnp.asarray(INJECTION, dtype=jnp.float64),
         jnp.asarray(BACKGROUND, dtype=jnp.float64)], axis=1)


def initial_grid(ny, nx):
    """Initial solute planes (background water everywhere)."""
    bg = jnp.asarray(BACKGROUND, dtype=jnp.float64)
    return jnp.broadcast_to(bg[:, None, None], (N_SOLUTES, ny, nx)).copy()
