"""L1 Pallas kernel: explicit upwind advection for the POET transport step.

The paper's POET setup uses "an explicit upwind advection scheme as transport
with constant fluxes" on a 500x1500 grid, injecting MgCl2 from the top-left
boundary.  This kernel advances all solute species one time step with a
first-order upwind stencil for a constant velocity field (vx, vy >= 0, flow
to the right and downward):

    c' = c - cfx * (c - c_west) - cfy * (c - c_north)

Boundary handling: the west ghost column and the north ghost row are inflow
boundaries.  Inflow concentration is ``inj`` (injection water) for the first
``inj_rows`` rows of the west boundary and ``bg`` (background water)
elsewhere — that is the paper's "constant injection ... from the top left
boundary of the grid".  Mineral species do not advect; the caller only passes
solute planes.

Hardware adaptation: classic halo stencil.  The grid iterates over (species,
row-block); each program instance sees its row block plus the row-block above
via a second BlockSpec on the same operand (an explicit HBM->VMEM halo
schedule — the TPU analogue of the threadblock ghost-zone staging a CUDA
version would do in shared memory).  interpret=True on this CPU-only box.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

ROW_BLOCK = 16


def _adv_kernel(inj_rows_ref, c_ref, cn_ref, inflow_ref, cf_ref, out_ref):
    """One (species, row-block) tile of the upwind update.

    c_ref:      (1, RB, nx) current block rows of this species plane
    cn_ref:     (1, RB, nx) the row-block one north (block 0 duplicates itself)
    inflow_ref: (1, 2)      [inj, bg] inflow concentration for this species
    cf_ref:     (2,)        [cfx, cfy] Courant numbers (whole array)
    inj_rows_ref: (1,)      rows fed by injection water (whole array)
    """
    c = c_ref[0]
    cn = cn_ref[0]
    rb, nx = c.shape
    blk = pl.program_id(1)
    inj, bg = inflow_ref[0, 0], inflow_ref[0, 1]
    cfx, cfy = cf_ref[0], cf_ref[1]
    inj_rows = inj_rows_ref[0]

    # global row / column index of each element in this block
    rows = blk * rb + jax.lax.broadcasted_iota(jnp.int32, (rb, nx), 0)

    # west neighbour: shift right; ghost column = inflow (inj for top rows)
    west_ghost = jnp.where(rows[:, :1] < inj_rows, inj, bg)
    c_west = jnp.concatenate([west_ghost, c[:, :-1]], axis=1)

    # north neighbour: first row of the block comes from cn's last row;
    # global row 0 uses the background inflow ghost row.
    c_north = jnp.concatenate([cn[-1:, :], c[:-1, :]], axis=0)
    c_north = jnp.where(rows == 0, bg, c_north)

    out_ref[0] = c - cfx * (c - c_west) - cfy * (c - c_north)


def advect_step(c, inflow, cf, inj_rows):
    """Upwind-advect solute planes one step.

    c:       f64[ns, ny, nx]  solute concentration planes
    inflow:  f64[ns, 2]       per-species [injection, background] inflow
    cf:      f64[2]           [cfx, cfy] Courant numbers (cfx+cfy <= 1)
    inj_rows: int             rows (from the top) fed by injection water
    Returns f64[ns, ny, nx].
    """
    ns, ny, nx = c.shape
    rb = ROW_BLOCK if ny % ROW_BLOCK == 0 else ny
    nblk = ny // rb
    inj_arr = jnp.asarray(inj_rows, dtype=jnp.int32).reshape(1)
    cf = jnp.asarray(cf, dtype=c.dtype)
    inflow = jnp.asarray(inflow, dtype=c.dtype)
    return pl.pallas_call(
        _adv_kernel,
        grid=(ns, nblk),
        in_specs=[
            pl.BlockSpec((1,), lambda s, i: (0,)),           # inj_rows
            pl.BlockSpec((1, rb, nx), lambda s, i: (s, i, 0)),
            # same operand, one row-block north (clamped at block 0)
            pl.BlockSpec((1, rb, nx), lambda s, i: (s, jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((1, 2), lambda s, i: (s, 0)),       # inflow
            pl.BlockSpec((2,), lambda s, i: (0,)),           # cf
        ],
        out_specs=pl.BlockSpec((1, rb, nx), lambda s, i: (s, i, 0)),
        out_shape=jax.ShapeDtypeStruct((ns, ny, nx), c.dtype),
        interpret=True,
    )(inj_arr, c, c, inflow, cf)
