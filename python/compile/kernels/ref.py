"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: pytest (and hypothesis) check the
Pallas kernels in ``chemistry.py`` / ``advection.py`` against these
implementations across shapes, and ``aot.py`` emits golden vectors computed
with the real kernels that the Rust runtime integration tests replay.
"""

import jax
import jax.numpy as jnp

from . import chemistry as chem

jax.config.update("jax_enable_x64", True)


def _rates_ref(ca, mg, c, ph, calcite, dolomite):
    """Independently-written TST rates (mirrors chemistry.py's model)."""
    h = 10.0 ** (-ph)
    denom = h * h + chem.K1 * h + chem.K1 * chem.K2
    a_co3 = c * (chem.K1 * chem.K2) / denom
    omega_cal = jnp.minimum(ca * a_co3 / chem.KSP_CAL, chem.OMEGA_CAP)
    omega_dol = jnp.minimum(ca * mg * a_co3 ** 2 / chem.KSP_DOL, chem.OMEGA_CAP)
    r_cal = chem.K_CAL * (1.0 - omega_cal)
    r_dol = chem.K_DOL * (1.0 - omega_dol)
    r_cal = jnp.where(r_cal > 0.0,
                      r_cal * calcite / (calcite + chem.M_HALF), r_cal)
    r_dol = jnp.where(r_dol > 0.0,
                      r_dol * dolomite / (dolomite + chem.M_HALF), r_dol)
    return r_cal, r_dol, omega_cal, omega_dol


def chemistry_step_ref(batch):
    """Reference kinetic chemistry step: f64[B, 10] -> f64[B, 13].

    Same chemical model as the kernel, but structured independently: a plain
    Python sub-step loop over vectorized jnp ops (no pallas, no tiling, no
    fori_loop), so tiling/loop bugs in the kernel cannot hide here.
    """
    batch = jnp.asarray(batch, dtype=jnp.float64)
    ca, mg, c = batch[:, 0], batch[:, 1], batch[:, 2]
    cl, ph, pe, o0 = batch[:, 3], batch[:, 4], batch[:, 5], batch[:, 6]
    calcite, dolomite = batch[:, 7], batch[:, 8]
    dts = batch[:, 9] / chem.N_SUB

    for _ in range(chem.N_SUB):
        r_cal, r_dol, _, _ = _rates_ref(ca, mg, c, ph, calcite, dolomite)
        # budget-limited extents (see chemistry.py): dissolution bounded by
        # the mineral, precipitation bounded by the solute budgets, both
        # bounded by the relative stability cap
        cap_dol = chem.EXT_CAP * (jnp.minimum(ca, mg) + chem.EXT_CAP_FLOOR)
        cap_cal = chem.EXT_CAP * (ca + chem.EXT_CAP_FLOOR)
        d_dol = jnp.clip(r_dol * dts, -cap_dol, cap_dol)
        d_dol = jnp.minimum(d_dol, dolomite)
        d_dol = jnp.maximum(d_dol, -(mg - chem.STATE_MIN))
        d_dol = jnp.maximum(d_dol, -(ca - chem.STATE_MIN))
        d_dol = jnp.maximum(d_dol, -0.5 * (c - chem.STATE_MIN))
        d_cal = jnp.clip(r_cal * dts, -cap_cal, cap_cal)
        d_cal = jnp.minimum(d_cal, calcite)
        d_cal = jnp.maximum(d_cal, -(ca - chem.STATE_MIN) - d_dol)
        d_cal = jnp.maximum(d_cal, -(c - chem.STATE_MIN) - 2.0 * d_dol)
        ca = ca + d_cal + d_dol
        mg = mg + d_dol
        c = c + d_cal + 2.0 * d_dol
        ph = jnp.clip(ph + chem.PH_BETA * (d_cal + 2.0 * d_dol), 4.0, 11.0)
        calcite = jnp.maximum(calcite - d_cal, 0.0)
        dolomite = jnp.maximum(dolomite - d_dol, 0.0)

    r_cal, r_dol, omega_cal, omega_dol = _rates_ref(
        ca, mg, c, ph, calcite, dolomite)
    return jnp.stack(
        [ca, mg, c, cl, ph, pe, o0, calcite, dolomite,
         r_cal, r_dol, omega_cal, omega_dol], axis=1)


def advect_step_ref(c, inflow, cf, inj_rows):
    """Reference upwind advection: f64[ns, ny, nx] -> f64[ns, ny, nx]."""
    c = jnp.asarray(c, dtype=jnp.float64)
    inflow = jnp.asarray(inflow, dtype=jnp.float64)
    ns, ny, nx = c.shape
    cfx, cfy = float(cf[0]), float(cf[1])

    rows = jnp.arange(ny)[:, None]
    inj = inflow[:, 0][:, None, None]
    bg = inflow[:, 1][:, None, None]

    west_ghost = jnp.where(rows[None, :, :1] < inj_rows, inj, bg)
    c_west = jnp.concatenate([jnp.broadcast_to(west_ghost, (ns, ny, 1)),
                              c[:, :, :-1]], axis=2)
    north_ghost = jnp.broadcast_to(bg, (ns, 1, nx))
    c_north = jnp.concatenate([north_ghost, c[:, :-1, :]], axis=1)
    return c - cfx * (c - c_west) - cfy * (c - c_north)
