"""L1 Pallas kernel: batched calcite/dolomite kinetic geochemistry.

This is the compute hot-spot of the POET reproduction — the stand-in for
PHREEQC [Parkhurst & Appelo 2013] in the paper's coupled reactive transport
simulation.  One call integrates the kinetic reaction network for a *batch*
of grid cells over one transport time step ``dt`` using ``N_SUB`` explicit
sub-steps.

State vector per cell (9 species, all f64, matching the paper's 80-byte key
= 9 species + dt):

    0 Ca       total dissolved calcium        [mol/kgw]
    1 Mg       total dissolved magnesium      [mol/kgw]
    2 C        total dissolved inorganic C    [mol/kgw]
    3 Cl       chloride (conservative)        [mol/kgw]
    4 pH       -log10 a(H+)
    5 pe       redox potential (conservative here)
    6 O0       dissolved oxygen (conservative here)
    7 Calcite  mineral amount                 [mol/L medium]
    8 Dolomite mineral amount                 [mol/L medium]

Output per cell (13 doubles, matching the paper's 104-byte value):

    0..8   updated state vector
    9      r_cal   net calcite dissolution rate  [mol/kgw/s]  (+ = dissolving)
    10     r_dol   net dolomite dissolution rate
    11     omega_cal  calcite saturation ratio at the end of the step
    12     omega_dol  dolomite saturation ratio

Chemistry model (simplified PHREEQC kinetic block, TST rate laws):

    carbonate speciation from pH:  a_CO3 = C * K1*K2 / (h^2 + K1*h + K1*K2)
    omega_cal = a_Ca * a_CO3 / Ksp_cal
    omega_dol = a_Ca * a_Mg * a_CO3^2 / Ksp_dol
    r = k * (1 - omega)            (+ dissolution, - precipitation)
    dissolution is gated on remaining mineral with a smooth surface-area
    factor m/(m + m_half), so minerals never go (much) below zero and the
    reaction front sharpens exactly like the paper describes: injected MgCl2
    supersaturates dolomite -> precipitation consumes Ca/CO3 -> calcite
    dissolves -> once calcite is exhausted dolomite redissolves.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): PHREEQC is CPU code;
here the kinetic integrator becomes a batched, VMEM-resident sub-step loop.
BlockSpec tiles the batch dimension in chunks of ``TILE_B`` cells; one tile
(``TILE_B x 10`` in + ``TILE_B x 13`` out, f64) is ~23 KB — with double
buffering far inside a 16 MB VMEM budget, so the whole sub-step loop runs
without HBM round-trips.  The work is element-wise transcendental (exp/log)
-> VPU-bound, not MXU-bound.

The kernel MUST be run with interpret=True on this CPU-only box (real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# --- thermodynamic / kinetic constants (25 C, I=0 simplification) ---------
LOG_K1 = -6.35       # H2CO3* = H+ + HCO3-
LOG_K2 = -10.33      # HCO3-  = H+ + CO3--
LOG_KSP_CAL = -8.48  # calcite  CaCO3 = Ca++ + CO3--
LOG_KSP_DOL = -17.09 # dolomite CaMg(CO3)2 = Ca++ + Mg++ + 2 CO3--

K1 = 10.0 ** LOG_K1
K2 = 10.0 ** LOG_K2
KSP_CAL = 10.0 ** LOG_KSP_CAL
KSP_DOL = 10.0 ** LOG_KSP_DOL

K_CAL = 1.5e-6       # calcite rate constant  [mol/kgw/s]
K_DOL = 3.0e-7       # dolomite rate constant [mol/kgw/s]
M_HALF = 1.0e-5      # half-saturation mineral amount for the surface factor
PH_BETA = 150.0      # pH response to net carbonate dissolution
OMEGA_CAP = 1.0e3    # cap on saturation ratio (keeps explicit steps stable)
#: per-substep relative extent cap — bounds the pH/omega feedback loop gain
#: so the explicit integrator is stable for transport steps dt <= ~2500 s
EXT_CAP = 0.25
EXT_CAP_FLOOR = 1.0e-4

N_SUB = 8            # kinetic sub-steps per transport step
NSPECIES = 9
NIN = 10             # 9 species + dt
NOUT = 13            # 9 species + 2 rates + 2 omegas
TILE_B = 128         # batch tile (VMEM-resident)

STATE_MIN = 1.0e-12  # concentration floor (solutes)


def _rates(ca, mg, c, ph, calcite, dolomite):
    """TST net dissolution rates and saturation ratios. Shared by kernel/ref."""
    h = jnp.power(10.0, -ph)
    denom = h * h + K1 * h + K1 * K2
    a_co3 = c * (K1 * K2) / denom
    omega_cal = jnp.minimum(ca * a_co3 / KSP_CAL, OMEGA_CAP)
    omega_dol = jnp.minimum(ca * mg * a_co3 * a_co3 / KSP_DOL, OMEGA_CAP)
    # surface-area factor: dissolution slows smoothly as the mineral runs out
    f_cal = calcite / (calcite + M_HALF)
    f_dol = dolomite / (dolomite + M_HALF)
    r_cal = K_CAL * (1.0 - omega_cal)
    r_dol = K_DOL * (1.0 - omega_dol)
    r_cal = jnp.where(r_cal > 0.0, r_cal * f_cal, r_cal)
    r_dol = jnp.where(r_dol > 0.0, r_dol * f_dol, r_dol)
    return r_cal, r_dol, omega_cal, omega_dol


def _integrate(state, dt):
    """Integrate one batch tile: state (B, 10) incl. dt column -> (B, 13)."""
    ca, mg, c = state[:, 0], state[:, 1], state[:, 2]
    cl, ph, pe, o0 = state[:, 3], state[:, 4], state[:, 5], state[:, 6]
    calcite, dolomite = state[:, 7], state[:, 8]
    dts = dt / N_SUB

    def sub(_, carry):
        ca, mg, c, ph, calcite, dolomite = carry
        r_cal, r_dol, _, _ = _rates(ca, mg, c, ph, calcite, dolomite)
        # Budget-limited reaction extents keep stoichiometry exact:
        # dissolution (+) cannot exceed the mineral present; precipitation
        # (-) cannot drive any solute below STATE_MIN.  Limiting the extents
        # (rather than clamping solutes afterwards) preserves mass balance.
        # The relative caps bound the per-substep state change, which keeps
        # the explicit pH/omega feedback loop stable (gain < 1).
        cap_dol = EXT_CAP * (jnp.minimum(ca, mg) + EXT_CAP_FLOOR)
        cap_cal = EXT_CAP * (ca + EXT_CAP_FLOOR)
        d_dol = jnp.clip(r_dol * dts, -cap_dol, cap_dol)
        d_dol = jnp.minimum(d_dol, dolomite)
        d_dol = jnp.maximum(d_dol, -(mg - STATE_MIN))
        d_dol = jnp.maximum(d_dol, -(ca - STATE_MIN))
        d_dol = jnp.maximum(d_dol, -0.5 * (c - STATE_MIN))
        d_cal = jnp.clip(r_cal * dts, -cap_cal, cap_cal)
        d_cal = jnp.minimum(d_cal, calcite)
        d_cal = jnp.maximum(d_cal, -(ca - STATE_MIN) - d_dol)
        d_cal = jnp.maximum(d_cal, -(c - STATE_MIN) - 2.0 * d_dol)
        ca = ca + d_cal + d_dol
        mg = mg + d_dol
        c = c + d_cal + 2.0 * d_dol
        ph = jnp.clip(ph + PH_BETA * (d_cal + 2.0 * d_dol), 4.0, 11.0)
        calcite = jnp.maximum(calcite - d_cal, 0.0)
        dolomite = jnp.maximum(dolomite - d_dol, 0.0)
        return ca, mg, c, ph, calcite, dolomite

    ca, mg, c, ph, calcite, dolomite = jax.lax.fori_loop(
        0, N_SUB, sub, (ca, mg, c, ph, calcite, dolomite)
    )
    r_cal, r_dol, omega_cal, omega_dol = _rates(ca, mg, c, ph, calcite, dolomite)
    return jnp.stack(
        [ca, mg, c, cl, ph, pe, o0, calcite, dolomite,
         r_cal, r_dol, omega_cal, omega_dol],
        axis=1,
    )


def _chem_kernel(in_ref, out_ref):
    """Pallas kernel body: one VMEM-resident batch tile through N_SUB steps."""
    state = in_ref[...]
    out_ref[...] = _integrate(state, state[:, 9])


def chemistry_step(batch):
    """Batched kinetic chemistry step.

    batch: f64[B, 10] — 9 species + dt per cell; B must be a multiple of the
    tile size (or small enough to be a single tile). Returns f64[B, 13].
    """
    b = batch.shape[0]
    tile = TILE_B if b % TILE_B == 0 else b
    grid = b // tile
    return pl.pallas_call(
        _chem_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((tile, NIN), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, NOUT), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, NOUT), jnp.float64),
        interpret=True,
    )(batch)
